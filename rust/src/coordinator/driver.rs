//! The batching driver: queue single-band transform jobs, flush them as one
//! batched distributed execution.
//!
//! Every rank runs one driver; jobs must be submitted in the same order on
//! every rank (the usual SPMD contract). On `flush`, the queued bands are
//! interleaved into one batch-fastest block, pushed through a batched
//! slab-pencil plan (one alltoall per stage for the whole batch), and the
//! results are handed back per job.

use std::sync::Arc;

use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::grid::ProcGrid;
use crate::fftb::plan::{ExecTrace, SlabPencilPlan};

/// One queued single-band transform request.
pub struct TransformJob {
    pub id: u64,
    pub data: Vec<Complex>,
    pub dir: Direction,
}

/// Collects jobs and executes them as one batched transform per direction.
pub struct BatchingDriver {
    shape: [usize; 3],
    grid: Arc<ProcGrid>,
    queue: Vec<TransformJob>,
    /// Completed results by job id.
    pub completed: Vec<(u64, Vec<Complex>)>,
    /// Traces of each flush (for the metrics sink).
    pub traces: Vec<ExecTrace>,
}

impl BatchingDriver {
    pub fn new(shape: [usize; 3], grid: Arc<ProcGrid>) -> Self {
        BatchingDriver { shape, grid, queue: Vec::new(), completed: Vec::new(), traces: Vec::new() }
    }

    pub fn submit(&mut self, job: TransformJob) {
        self.queue.push(job);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Flush all queued jobs of direction `dir` as ONE batched execution.
    /// Returns the number of jobs executed.
    pub fn flush(&mut self, backend: &dyn LocalFftBackend, dir: Direction) -> usize {
        let jobs: Vec<TransformJob> = {
            let (take, keep): (Vec<_>, Vec<_>) =
                std::mem::take(&mut self.queue).into_iter().partition(|j| j.dir == dir);
            self.queue = keep;
            take
        };
        if jobs.is_empty() {
            return 0;
        }
        let nb = jobs.len();
        let plan = SlabPencilPlan::new(self.shape, nb, Arc::clone(&self.grid))
            .expect("driver shape/grid mismatch");
        // Batched local lengths are nb x the single-band ones, so the
        // per-band job length comes straight off the batched plan.
        let per_band = match dir {
            Direction::Forward => plan.input_len() / nb,
            Direction::Inverse => plan.output_len() / nb,
        };

        // Interleave bands (batch fastest).
        let mut block = vec![ZERO; nb * per_band];
        for (b, job) in jobs.iter().enumerate() {
            assert_eq!(job.data.len(), per_band, "job {b} has wrong local length");
            for (e, v) in job.data.iter().enumerate() {
                block[b + nb * e] = *v;
            }
        }
        let (out, trace) = match dir {
            Direction::Forward => plan.forward(backend, block),
            Direction::Inverse => plan.inverse(backend, block),
        };
        self.traces.push(trace);

        // De-interleave.
        let out_per_band = out.len() / nb;
        for (b, job) in jobs.into_iter().enumerate() {
            let band: Vec<Complex> =
                (0..out_per_band).map(|e| out[b + nb * e]).collect();
            self.completed.push((job.id, band));
        }
        self.completed.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{phased, scatter_cube_x};

    #[test]
    fn flush_matches_individual_transforms() {
        let shape = [8usize, 8, 8];
        let p = 2;
        let outs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));

            // Three single-band jobs.
            let bands: Vec<Vec<Complex>> = (0..3)
                .map(|b| {
                    let g = phased(512, b as u64);
                    scatter_cube_x(&g, 1, shape, p, grid.rank())
                })
                .collect();
            for (i, b) in bands.iter().enumerate() {
                driver.submit(TransformJob {
                    id: i as u64,
                    data: b.clone(),
                    dir: Direction::Forward,
                });
            }
            assert_eq!(driver.pending(), 3);
            let done = driver.flush(&backend, Direction::Forward);
            assert_eq!(done, 3);
            assert_eq!(driver.pending(), 0);
            // One batched alltoall, not three.
            assert_eq!(driver.traces.len(), 1);
            assert_eq!(driver.traces[0].comm_messages(), (p - 1) as u64);

            // Each result equals the single-band plan's output.
            let single = SlabPencilPlan::new(shape, 1, Arc::clone(&grid)).unwrap();
            let mut ok = true;
            for (id, got) in &driver.completed {
                let (want, _) = single.forward(&backend, bands[*id as usize].clone());
                ok &= crate::fft::complex::max_abs_diff(got, &want) < 1e-12;
            }
            ok
        });
        assert!(outs.iter().all(|&b| b));
    }

    #[test]
    fn flush_is_direction_selective() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            driver.submit(TransformJob { id: 0, data: vec![ZERO; 64], dir: Direction::Forward });
            driver.submit(TransformJob { id: 1, data: vec![ZERO; 64], dir: Direction::Inverse });
            driver.flush(&backend, Direction::Forward);
            assert_eq!(driver.pending(), 1, "inverse job stays queued");
        });
    }
}
