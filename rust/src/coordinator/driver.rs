//! The batching driver: queue single-band transform jobs, flush them as one
//! batched distributed execution.
//!
//! Every rank runs one driver; jobs must be submitted in the same order on
//! every rank (the usual SPMD contract). On `flush`, the queued bands are
//! interleaved into one batch-fastest block, pushed through a batched
//! slab-pencil plan (one alltoall per stage for the whole batch), and the
//! results are handed back per job.
//!
//! Plans are drawn from a per-driver [`PlanCache`] keyed by
//! `(shape, nb, sphere, window, worker)` (direction-agnostic: one plan
//! serves both directions): the first flush of a given batch size
//! plans and warms a workspace, every later flush reuses both. The
//! exchange window is either fixed at construction
//! ([`BatchingDriver::with_tuning`]) or resolved per batch size through
//! the tuner's cost model ([`BatchingDriver::with_auto_window`] →
//! [`search::auto_window`](crate::tuner::search::auto_window)), so a
//! 2-job flush and a 64-job flush each get the window the model prefers
//! for their message sizes —
//! `ExecTrace::plan_cache_hit` reports which happened, and steady-state
//! flushes are allocation-free (`alloc_bytes == 0`) because the cached
//! plan's workspace and slot pool survive between flushes. The flush path
//! itself is allocation-lean: the queue partition and the interleave block
//! run through driver-owned reusable buffers, and the batch output is
//! recycled as the next flush's block. Results accumulate until the caller
//! collects them with [`BatchingDriver::drain_completed`] (and traces with
//! [`BatchingDriver::drain_traces`]).
//!
//! ## The two-deep pipeline
//!
//! [`BatchingDriver::with_pipeline_depth`] at depth 2 gives the driver a
//! persistent helper thread ([`Worker`]): each flush's de-interleave tail
//! (batched output → per-job result vectors) is shipped to the worker,
//! which runs it while the *next* flush's interleave and exchange occupy
//! the main thread. The tail owns its data outright (the batch output and
//! the jobs move through the channel) and signals completion on a response
//! channel, so there is no shared mutation; interleave blocks are
//! double-buffered (one riding the worker, one on the main thread) and the
//! pool never grows past two. Harvesting is deferred to the latest safe
//! point — the next flush (after its execute), a drain, or a pool-empty
//! checkout — and folds the worker's time into that flush's trace as
//! `worker_busy_ns` / `pipeline_overlap_ns`. Depth 1 (the default) runs
//! the identical tail code inline; the two depths are bit-identical by
//! construction.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::comm::alltoall::CommTuning;
use crate::comm::worker::Worker;
use crate::fft::complex::{Complex, ZERO};
use crate::fft::dft::Direction;
use crate::fftb::backend::LocalFftBackend;
use crate::fftb::error::{FftbError, Result};
use crate::fftb::grid::ProcGrid;
use crate::fftb::plan::{ExecTrace, Fftb, PlanKind, PlaneWavePlan, SlabPencilPlan};
use crate::fftb::sphere::OffsetArray;
use crate::model::machine::Machine;
use crate::tuner::cache::{PlanCache, PlanKey};
use crate::tuner::search::{self, CandidateKind, TuneRequest, WorkloadProfile};

/// One queued single-band transform request.
pub struct TransformJob {
    /// Caller-chosen identifier, returned with the result.
    pub id: u64,
    /// This rank's local slice of the band.
    pub data: Vec<Complex>,
    /// Transform direction the job wants.
    pub dir: Direction,
}

/// A flush's deferred de-interleave tail, in flight on the worker thread.
struct PendingTail {
    /// Completion channel: the de-interleaved jobs (results in their own
    /// vectors), the batch-output block for the pool, and the tail's
    /// elapsed nanoseconds.
    rx: mpsc::Receiver<(Vec<TransformJob>, Vec<Complex>, u64)>,
    /// Index into `traces` of the flush this tail belongs to (valid until
    /// `drain_traces`, which harvests first).
    trace_idx: usize,
}

/// De-interleave the batch-fastest output block back into each job's own
/// vector — the submitted storage becomes the result storage, so the tail
/// mints no per-band vectors. Shared verbatim by the inline (depth-1) and
/// worker (depth-2) tails, so the two pipeline depths are bit-identical by
/// construction.
fn deinterleave_into_jobs(out: &[Complex], nb: usize, jobs: &mut [TransformJob]) {
    let out_per_band = out.len() / nb;
    for (b, job) in jobs.iter_mut().enumerate() {
        job.data.clear();
        job.data.extend((0..out_per_band).map(|e| out[b + nb * e]));
    }
}

/// Collects jobs and executes them as one batched transform per direction.
pub struct BatchingDriver {
    shape: [usize; 3],
    grid: Arc<ProcGrid>,
    /// Identity of the grid's communicator, precomputed for the per-flush
    /// plan-cache key.
    comm_id: u64,
    tuning: CommTuning,
    /// When set, the exchange window is resolved per batch size through
    /// `tuner::search::auto_window` on this machine description instead of
    /// taking `tuning.window`.
    auto_machine: Option<Machine>,
    /// When set, this driver is a *sphere lane*: jobs carry packed
    /// plane-wave coefficients for this cut-off sphere, and flushes run
    /// batched [`PlaneWavePlan`]s (staged padding) instead of dense
    /// slab-pencil transforms. See [`BatchingDriver::with_sphere`].
    sphere: Option<Arc<OffsetArray>>,
    queue: Vec<TransformJob>,
    /// Reusable flush scratch: jobs taken this flush / jobs kept queued.
    take_buf: Vec<TransformJob>,
    keep_buf: Vec<TransformJob>,
    /// Spare job vector cycling through the worker tail at depth 2, so the
    /// handoff swaps vectors instead of minting one per flush.
    spare_jobs: Vec<TransformJob>,
    /// Reusable interleave blocks (recycled from previous flush outputs).
    /// Depth 1 cycles one block; depth 2 double-buffers (one riding the
    /// worker tail, one interleaving) and never holds more than two.
    block_pool: Vec<Vec<Complex>>,
    /// How many blocks the pool has ever minted — past two, an empty pool
    /// harvests the in-flight tail instead of allocating a third.
    blocks_minted: usize,
    /// Software-pipeline depth: 1 = synchronous tail (default), 2 = the
    /// tail runs on `worker` concurrently with the next flush's exchange.
    pipeline_depth: usize,
    /// The persistent helper thread (spawned at depth 2).
    worker: Option<Worker>,
    /// The previous flush's tail, if still in flight on the worker.
    pending_tail: Option<PendingTail>,
    /// Memoized plans, keyed by `(comm_id, shape, nb, sphere, window,
    /// worker)`; see `plan_for` for why the key is direction-agnostic.
    cache: PlanCache,
    /// Completed results by job id (collect with `drain_completed`).
    pub completed: Vec<(u64, Vec<Complex>)>,
    /// Traces of each flush (collect with `drain_traces`).
    pub traces: Vec<ExecTrace>,
}

impl BatchingDriver {
    /// A driver for batched slab-pencil transforms of `shape` on the 1D
    /// `grid`, with the default exchange tuning.
    pub fn new(shape: [usize; 3], grid: Arc<ProcGrid>) -> Self {
        Self::with_tuning(shape, grid, CommTuning::default())
    }

    /// [`BatchingDriver::new`] with explicit exchange overlap knobs for the
    /// plans the driver builds.
    pub fn with_tuning(shape: [usize; 3], grid: Arc<ProcGrid>, tuning: CommTuning) -> Self {
        let comm_id = grid.comm().identity();
        BatchingDriver {
            shape,
            grid,
            comm_id,
            tuning,
            auto_machine: None,
            sphere: None,
            queue: Vec::new(),
            take_buf: Vec::new(),
            keep_buf: Vec::new(),
            spare_jobs: Vec::new(),
            block_pool: Vec::new(),
            blocks_minted: 0,
            pipeline_depth: 1,
            worker: None,
            pending_tail: None,
            cache: PlanCache::new(),
            completed: Vec::new(),
            traces: Vec::new(),
        }
    }

    /// Set the software-pipeline depth: `1` (the default) runs each
    /// flush's de-interleave tail inline; `2` spawns the persistent
    /// [`Worker`] and ships the tail to it, overlapping it with the next
    /// flush's interleave + exchange. Results are identical bit-for-bit —
    /// only the schedule changes.
    pub fn with_pipeline_depth(mut self, depth: usize) -> Self {
        assert!((1..=2).contains(&depth), "pipeline depth must be 1 or 2, got {depth}");
        self.pipeline_depth = depth;
        if depth >= 2 && self.worker.is_none() {
            self.worker = Some(Worker::spawn());
        }
        self
    }

    /// The configured software-pipeline depth (1 or 2).
    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// A *sphere-lane* driver: jobs submit packed plane-wave coefficients
    /// for the cut-off sphere `off` (this rank's slice is the cyclic
    /// x-restriction, [`OffsetArray::restrict_x_cyclic`]) and flushes run
    /// batched [`PlaneWavePlan`]s — forward jobs carry
    /// `off.restrict_x_cyclic(p, r).total()` elements and come back dense,
    /// inverse jobs the reverse. The sphere's structural fingerprint joins
    /// the plan-cache key, so two lanes over different spheres never share
    /// a plan even at the same shape and batch size.
    pub fn with_sphere(
        shape: [usize; 3],
        grid: Arc<ProcGrid>,
        off: Arc<OffsetArray>,
        tuning: CommTuning,
    ) -> Result<Self> {
        if shape != [off.nx, off.ny, off.nz] {
            return Err(FftbError::Shape(format!(
                "sphere offsets describe a {}x{}x{} grid but the driver shape is {shape:?}",
                off.nx, off.ny, off.nz
            )));
        }
        if grid.ndim() != 1 {
            return Err(FftbError::Grid(format!(
                "sphere lanes need a 1D processing grid, got {}D",
                grid.ndim()
            )));
        }
        let mut d = Self::with_tuning(shape, grid, tuning);
        d.sphere = Some(off);
        Ok(d)
    }

    /// A driver that resolves its exchange window through the tuner's cost
    /// model instead of a fixed `CommTuning`: every flush prices the
    /// batched slab-pencil stage table for its *actual* batch size on
    /// `machine` ([`search::auto_window`]) and plans with the cheapest
    /// window. Deterministic across ranks (worst-rank stage counts), and
    /// the resolved window is part of the plan-cache key, so a batch size
    /// whose optimum differs gets its own plan.
    pub fn with_auto_window(shape: [usize; 3], grid: Arc<ProcGrid>, machine: Machine) -> Self {
        let mut d = Self::new(shape, grid);
        d.auto_machine = Some(machine);
        d
    }

    /// The exchange window a flush of `nb` jobs will use: the model's pick
    /// when the driver was built with [`BatchingDriver::with_auto_window`],
    /// the fixed `CommTuning::window` otherwise.
    pub fn window_for(&self, nb: usize) -> usize {
        match &self.auto_machine {
            Some(m) => {
                let kind = match &self.sphere {
                    Some(_) => CandidateKind::PlaneWave,
                    None => CandidateKind::SlabPencil,
                };
                search::auto_window(
                    kind,
                    &TuneRequest {
                        shape: self.shape,
                        nb,
                        p: self.grid.size(),
                        sphere: self.sphere.clone(),
                        profile: WorkloadProfile::Forward,
                        real: false,
                    },
                    m,
                )
            }
            None => self.tuning.window,
        }
    }

    /// Enqueue one job (same order on every rank).
    pub fn submit(&mut self, job: TransformJob) {
        self.queue.push(job);
    }

    /// Number of jobs waiting for a flush.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// `(hits, misses)` of the driver's plan cache.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        self.cache.stats()
    }

    /// Take all completed `(id, result)` pairs, leaving the driver's
    /// completed list empty — call after each flush round so results do
    /// not accumulate unboundedly across an SCF run. Harvests any
    /// in-flight pipeline tail first, so the last flush's results are
    /// always included and the list stays FIFO in submission order.
    pub fn drain_completed(&mut self) -> Vec<(u64, Vec<Complex>)> {
        self.harvest_pending();
        std::mem::take(&mut self.completed)
    }

    /// Take all flush traces accumulated since the last drain. Harvests
    /// any in-flight pipeline tail first, so every returned trace carries
    /// its final `worker_busy_ns` / `pipeline_overlap_ns`.
    pub fn drain_traces(&mut self) -> Vec<ExecTrace> {
        self.harvest_pending();
        std::mem::take(&mut self.traces)
    }

    /// Complete the previous flush's deferred tail, if one is in flight:
    /// block until the worker signals, move its results into `completed`
    /// (FIFO across flushes), return its buffers to the pools, and fold
    /// the worker's time into that flush's trace.
    fn harvest_pending(&mut self) {
        if let Some(tail) = self.pending_tail.take() {
            if let Ok((mut jobs, out, busy_ns)) = tail.rx.recv() {
                for job in jobs.drain(..) {
                    self.completed.push((job.id, job.data));
                }
                self.spare_jobs = jobs;
                self.block_pool.push(out);
                if let Some(tr) = self.traces.get_mut(tail.trace_idx) {
                    tr.worker_busy_ns += busy_ns;
                    tr.pipeline_overlap_ns += busy_ns;
                }
            }
        }
    }

    /// Grab an interleave block. The pool is double-buffered: at depth 2
    /// one block rides the worker tail while the next flush interleaves
    /// into the other. Once two blocks exist, an empty pool harvests the
    /// in-flight tail (blocking) instead of minting a third, so steady
    /// state allocates nothing.
    fn checkout_block(&mut self) -> Vec<Complex> {
        if let Some(b) = self.block_pool.pop() {
            return b;
        }
        if self.pending_tail.is_some() && self.blocks_minted >= 2 {
            self.harvest_pending();
            if let Some(b) = self.block_pool.pop() {
                return b;
            }
        }
        self.blocks_minted += 1;
        Vec::new()
    }

    /// Fetch (or build and cache) the batched plan for `nb` bands. The key
    /// is direction-agnostic (`dir: None`): a slab-pencil plan precomputes
    /// both exchange schedules, so forward and inverse flushes of the same
    /// batch size share one plan — and one warmed workspace. The window
    /// (fixed or model-resolved, see [`BatchingDriver::window_for`]) is
    /// part of the key.
    fn plan_for(&mut self, nb: usize) -> Result<(Arc<Fftb>, bool)> {
        let window = self.window_for(nb);
        // Static string keys: the per-flush lookup allocates nothing.
        let (signature, kind, sphere_fp) = match &self.sphere {
            Some(off) => ("driver:sphere", "plane-wave", off.fingerprint()),
            None => ("driver:slab", "slab-pencil", 0),
        };
        let key = PlanKey {
            comm_id: self.comm_id,
            sizes: self.shape,
            signature: signature.into(),
            kind: kind.into(),
            nb,
            dir: None,
            sphere: sphere_fp,
            window,
            worker: self.tuning.worker,
            r2c: false,
        };
        let (shape, grid) = (self.shape, Arc::clone(&self.grid));
        let worker = self.tuning.worker;
        let sphere = self.sphere.clone();
        self.cache.get_or_insert(key, || {
            let kind = match sphere {
                Some(off) => PlanKind::PlaneWave(PlaneWavePlan::new(off, nb, grid)?),
                None => PlanKind::SlabPencil(SlabPencilPlan::new(shape, nb, grid)?),
            };
            let mut fx = Fftb { kind, sizes: shape, nb };
            fx.set_comm_tuning(CommTuning::with_window(window).with_worker(worker));
            Ok(fx)
        })
    }

    /// Flush all queued jobs of direction `dir` as ONE batched execution.
    /// Returns the number of jobs executed.
    pub fn flush(&mut self, backend: &dyn LocalFftBackend, dir: Direction) -> usize {
        // Partition in one pass through reusable buffers (no per-flush
        // vectors, stable job order).
        self.take_buf.clear();
        self.keep_buf.clear();
        for job in self.queue.drain(..) {
            if job.dir == dir {
                self.take_buf.push(job);
            } else {
                self.keep_buf.push(job);
            }
        }
        std::mem::swap(&mut self.queue, &mut self.keep_buf);
        if self.take_buf.is_empty() {
            return 0;
        }
        let nb = self.take_buf.len();
        // pallas-lint: allow(no-panic) — `enqueue` validated every job's
        // shape against the driver's grid, so planning the same shape at a
        // new batch width cannot fail here.
        let (plan, cache_hit) = self.plan_for(nb).expect("driver shape/grid mismatch");
        // Batched local lengths are nb x the single-band ones, so the
        // per-band job length comes straight off the batched plan.
        let per_band = match dir {
            Direction::Forward => plan.input_len() / nb,
            Direction::Inverse => plan.output_len() / nb,
        };

        // Interleave bands (batch fastest) into a pooled block. No clear
        // first: the loop below writes every element, so stale contents
        // never survive and the resize avoids a redundant memset.
        let mut block = self.checkout_block();
        block.resize(nb * per_band, ZERO);
        for (b, job) in self.take_buf.iter().enumerate() {
            assert_eq!(job.data.len(), per_band, "job {b} has wrong local length");
            for (e, v) in job.data.iter().enumerate() {
                block[b + nb * e] = *v;
            }
        }
        let (out, mut trace) = plan.execute(backend, block, dir);
        trace.plan_cache_hit = cache_hit;
        self.traces.push(trace);
        // The previous flush's tail has had this whole execute to finish
        // on the worker; harvest it now so `completed` stays FIFO across
        // flushes before this flush's results are (eventually) appended.
        self.harvest_pending();

        if self.pipeline_depth >= 2 && self.worker.is_some() {
            // Defer this flush's de-interleave to the worker: jobs and the
            // batch output move into the closure outright, results travel
            // back through the response channel at the next harvest point.
            let mut jobs =
                std::mem::replace(&mut self.take_buf, std::mem::take(&mut self.spare_jobs));
            let (tx, rx) = mpsc::channel();
            let trace_idx = self.traces.len() - 1;
            if let Some(worker) = &self.worker {
                worker.submit(move || {
                    let t0 = Instant::now();
                    deinterleave_into_jobs(&out, nb, &mut jobs);
                    let _ = tx.send((jobs, out, t0.elapsed().as_nanos() as u64));
                });
            }
            self.pending_tail = Some(PendingTail { rx, trace_idx });
        } else {
            // Depth 1: the identical tail, inline. The batch output
            // becomes a future flush's interleave block.
            deinterleave_into_jobs(&out, nb, &mut self.take_buf);
            for job in self.take_buf.drain(..) {
                self.completed.push((job.id, job.data));
            }
            self.block_pool.push(out);
        }
        nb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::communicator::run_world;
    use crate::fftb::backend::RustFftBackend;
    use crate::fftb::plan::testutil::{phased, scatter_cube_x};

    #[test]
    fn flush_matches_individual_transforms() {
        let shape = [8usize, 8, 8];
        let p = 2;
        let outs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));

            // Three single-band jobs.
            let bands: Vec<Vec<Complex>> = (0..3)
                .map(|b| {
                    let g = phased(512, b as u64);
                    scatter_cube_x(&g, 1, shape, p, grid.rank())
                })
                .collect();
            for (i, b) in bands.iter().enumerate() {
                driver.submit(TransformJob {
                    id: i as u64,
                    data: b.clone(),
                    dir: Direction::Forward,
                });
            }
            assert_eq!(driver.pending(), 3);
            let done = driver.flush(&backend, Direction::Forward);
            assert_eq!(done, 3);
            assert_eq!(driver.pending(), 0);
            // One batched alltoall, not three.
            assert_eq!(driver.traces.len(), 1);
            assert_eq!(driver.traces[0].comm_messages(), (p - 1) as u64);
            assert!(!driver.traces[0].plan_cache_hit, "first flush must plan");

            // Each result equals the single-band plan's output.
            let single = SlabPencilPlan::new(shape, 1, Arc::clone(&grid)).unwrap();
            let mut ok = true;
            for (id, got) in &driver.completed {
                let (want, _) = single.forward(&backend, bands[*id as usize].clone());
                ok &= crate::fft::complex::max_abs_diff(got, &want) < 1e-12;
            }
            ok
        });
        assert!(outs.iter().all(|&b| b));
    }

    #[test]
    fn flush_is_direction_selective() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            driver.submit(TransformJob { id: 0, data: vec![ZERO; 64], dir: Direction::Forward });
            driver.submit(TransformJob { id: 1, data: vec![ZERO; 64], dir: Direction::Inverse });
            driver.flush(&backend, Direction::Forward);
            assert_eq!(driver.pending(), 1, "inverse job stays queued");
        });
    }

    #[test]
    fn repeated_flushes_hit_the_plan_cache() {
        let shape = [8usize, 8, 8];
        let p = 2;
        run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            let band = || {
                let g = phased(512, 9);
                scatter_cube_x(&g, 1, shape, p, grid.rank())
            };
            for round in 0..4 {
                for i in 0..2u64 {
                    driver.submit(TransformJob { id: i, data: band(), dir: Direction::Forward });
                }
                driver.flush(&backend, Direction::Forward);
                let tr = driver.traces.last().unwrap();
                if round == 0 {
                    assert!(!tr.plan_cache_hit, "round 0 builds the plan");
                } else {
                    assert!(tr.plan_cache_hit, "round {round} must reuse the cached plan");
                    assert_eq!(
                        tr.alloc_bytes, 0,
                        "round {round}: cached plan's workspace must be warm"
                    );
                }
                driver.drain_completed();
            }
            let (hits, misses) = driver.plan_cache_stats();
            assert_eq!(misses, 1);
            assert_eq!(hits, 3);
        });
    }

    #[test]
    fn drain_completed_empties_and_returns_everything() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            for i in 0..3u64 {
                driver.submit(TransformJob {
                    id: i,
                    data: phased(64, i),
                    dir: Direction::Forward,
                });
            }
            driver.flush(&backend, Direction::Forward);
            let got = driver.drain_completed();
            assert_eq!(got.len(), 3);
            assert!(driver.completed.is_empty(), "drain must leave nothing behind");
            let ids: Vec<u64> = got.iter().map(|(id, _)| *id).collect();
            assert_eq!(ids, vec![0, 1, 2]);
            assert_eq!(driver.drain_traces().len(), 1);
            assert!(driver.traces.is_empty());
        });
    }

    #[test]
    fn forward_and_inverse_share_one_plan() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            for dir in [Direction::Forward, Direction::Inverse] {
                for i in 0..2u64 {
                    driver.submit(TransformJob { id: i, data: phased(64, i), dir });
                }
                driver.flush(&backend, dir);
            }
            assert_eq!(
                driver.plan_cache_stats(),
                (1, 1),
                "an inverse flush must reuse the forward flush's plan"
            );
            assert!(driver.traces[1].plan_cache_hit);
        });
    }

    #[test]
    fn auto_window_driver_resolves_through_the_tuner() {
        use crate::model::machine::Machine;
        use crate::tuner::search::{self, CandidateKind, TuneRequest, WorkloadProfile};

        let shape = [8usize, 8, 8];
        let p = 2;
        let outs = run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver =
                BatchingDriver::with_auto_window(shape, Arc::clone(&grid), Machine::local_cpu());
            // The resolved window must be exactly the tuner's window-only
            // search for the same request.
            let nb = 3usize;
            let want = search::auto_window(
                CandidateKind::SlabPencil,
                &TuneRequest {
                    shape,
                    nb,
                    p,
                    sphere: None,
                    profile: WorkloadProfile::Forward,
                    real: false,
                },
                &Machine::local_cpu(),
            );
            assert_eq!(driver.window_for(nb), want);

            // And flushes still work end-to-end, hitting the cache on
            // repeats of the same batch size.
            for _ in 0..2 {
                for i in 0..nb as u64 {
                    let g = phased(512, i);
                    driver.submit(TransformJob {
                        id: i,
                        data: scatter_cube_x(&g, 1, shape, p, grid.rank()),
                        dir: Direction::Forward,
                    });
                }
                assert_eq!(driver.flush(&backend, Direction::Forward), nb);
                driver.drain_completed();
            }
            driver.plan_cache_stats()
        });
        for (hits, misses) in outs {
            assert_eq!((hits, misses), (1, 1), "second flush must reuse the plan");
        }
    }

    #[test]
    fn pipeline_depth_two_matches_depth_one_bitwise() {
        let shape = [8usize, 8, 8];
        let p = 2;
        run_world(p, |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut d1 = BatchingDriver::new(shape, Arc::clone(&grid));
            let mut d2 = BatchingDriver::new(shape, Arc::clone(&grid)).with_pipeline_depth(2);
            assert_eq!(d1.pipeline_depth(), 1);
            assert_eq!(d2.pipeline_depth(), 2);

            let mut run = |driver: &mut BatchingDriver| {
                let mut got = Vec::new();
                for round in 0..3u64 {
                    for i in 0..3u64 {
                        let g = phased(512, round * 10 + i);
                        driver.submit(TransformJob {
                            id: round * 10 + i,
                            data: scatter_cube_x(&g, 1, shape, p, grid.rank()),
                            dir: Direction::Forward,
                        });
                    }
                    assert_eq!(driver.flush(&backend, Direction::Forward), 3);
                    // Depth 2 leaves the tail in flight here; the drain
                    // must harvest it before returning results.
                    got.extend(driver.drain_completed());
                }
                got
            };
            let r1 = run(&mut d1);
            let r2 = run(&mut d2);
            assert_eq!(r1.len(), 9);
            assert_eq!(r2.len(), 9);
            for ((id1, v1), (id2, v2)) in r1.iter().zip(&r2) {
                assert_eq!(id1, id2, "pipelining must not reorder results");
                assert_eq!(v1.len(), v2.len());
                for (a, b) in v1.iter().zip(v2) {
                    assert_eq!(a.re.to_bits(), b.re.to_bits());
                    assert_eq!(a.im.to_bits(), b.im.to_bits());
                }
            }
            // Harvest attributed the worker's time to the flush traces,
            // and the overlap tally is exactly the worker's busy time
            // (the exchange-level worker is off in this tuning).
            for tr in d2.drain_traces() {
                assert_eq!(tr.worker_busy_ns, tr.pipeline_overlap_ns);
            }
            assert!(d2.blocks_minted <= 2, "block pool must stay double-buffered");
        });
    }

    #[test]
    fn depth_two_double_buffers_without_intermediate_drains() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver =
                BatchingDriver::new(shape, Arc::clone(&grid)).with_pipeline_depth(2);
            // Four back-to-back flushes with no drain in between: each
            // flush's execute overlaps the previous flush's tail.
            for round in 0..4u64 {
                for i in 0..2u64 {
                    driver.submit(TransformJob {
                        id: round * 2 + i,
                        data: phased(64, round * 2 + i),
                        dir: Direction::Forward,
                    });
                }
                assert_eq!(driver.flush(&backend, Direction::Forward), 2);
            }
            let got = driver.drain_completed();
            let ids: Vec<u64> = got.iter().map(|(id, _)| *id).collect();
            assert_eq!(ids, (0..8).collect::<Vec<u64>>(), "FIFO across pipelined flushes");
            assert_eq!(driver.blocks_minted, 2, "exactly two interleave blocks circulate");
            let traces = driver.drain_traces();
            assert_eq!(traces.len(), 4);
            for (round, tr) in traces.iter().enumerate() {
                assert_eq!(tr.worker_busy_ns, tr.pipeline_overlap_ns);
                if round > 0 {
                    assert!(tr.plan_cache_hit, "round {round} must reuse the plan");
                    assert_eq!(tr.alloc_bytes, 0, "round {round} must stay warm");
                }
            }
        });
    }

    #[test]
    fn sphere_lane_flush_matches_single_plane_wave_plans() {
        use crate::fftb::plan::PlaneWavePlan;
        use crate::fftb::sphere::{SphereKind, SphereSpec};

        let n = 8usize;
        let p = 2;
        let spec = SphereSpec::new([n, n, n], 3.0, SphereKind::Wrapped);
        let off = Arc::new(spec.offsets());
        let off2 = Arc::clone(&off);
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::with_sphere(
                [n, n, n],
                Arc::clone(&grid),
                Arc::clone(&off2),
                CommTuning::default(),
            )
            .unwrap();
            let loc = off2.restrict_x_cyclic(p, grid.rank());
            let bands: Vec<Vec<Complex>> =
                (0..3).map(|b| phased(loc.total(), 40 + b as u64)).collect();
            for (i, b) in bands.iter().enumerate() {
                driver.submit(TransformJob {
                    id: i as u64,
                    data: b.clone(),
                    dir: Direction::Forward,
                });
            }
            assert_eq!(driver.flush(&backend, Direction::Forward), 3);
            // One fused exchange cadence for the whole batch, not three.
            assert_eq!(driver.traces.len(), 1);

            // Bit-identical to the single-band plane-wave plan per job.
            let single = PlaneWavePlan::new(Arc::clone(&off2), 1, Arc::clone(&grid)).unwrap();
            let mut ok = true;
            for (id, got) in driver.drain_completed() {
                let (want, _) = single.forward(&backend, bands[id as usize].clone());
                ok &= got.len() == want.len()
                    && got
                        .iter()
                        .zip(&want)
                        .all(|(a, b)| a.re.to_bits() == b.re.to_bits()
                            && a.im.to_bits() == b.im.to_bits());
            }
            ok
        });
        assert!(outs.iter().all(|&b| b));
    }

    #[test]
    fn sphere_lane_rejects_mismatched_shape() {
        use crate::fftb::sphere::{SphereKind, SphereSpec};
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let off = Arc::new(SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered).offsets());
            let e = BatchingDriver::with_sphere([4, 4, 4], grid, off, CommTuning::default());
            assert!(matches!(e, Err(crate::fftb::error::FftbError::Shape(_))));
        });
    }

    #[test]
    fn different_batch_sizes_are_distinct_cache_entries() {
        let shape = [4usize, 4, 4];
        run_world(1, |comm| {
            let grid = ProcGrid::new(&[1], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver = BatchingDriver::new(shape, Arc::clone(&grid));
            for nb in [2usize, 3, 2] {
                for i in 0..nb as u64 {
                    driver.submit(TransformJob {
                        id: i,
                        data: phased(64, i),
                        dir: Direction::Forward,
                    });
                }
                driver.flush(&backend, Direction::Forward);
            }
            // nb=2 twice (miss + hit), nb=3 once (miss).
            assert_eq!(driver.plan_cache_stats(), (1, 2));
        });
    }
}
