//! Metrics collection: aggregate [`ExecTrace`]s into table rows / CSV /
//! JSON for the benches and EXPERIMENTS.md.
//!
//! Atomics audit: this sink is deliberately single-threaded — traces are
//! merged across ranks *before* they arrive here (see
//! [`ExecTrace::critical_path`]), so it holds plain fields and no atomics.
//! The crate-wide atomic-ordering conventions live in
//! [`lint`](crate::lint) and `docs/ARCHITECTURE.md`.

use std::time::Duration;

use crate::fftb::plan::{ExecTrace, StageKind};
use crate::util::json::Json;

/// Aggregated view of one experiment configuration.
#[derive(Clone, Debug)]
pub struct MetricsSink {
    /// Configuration label printed in tables and JSON records.
    pub label: String,
    /// Per-run traces recorded so far, in call order.
    pub runs: Vec<ExecTrace>,
}

impl MetricsSink {
    /// An empty sink for the configuration named `label`.
    pub fn new(label: impl Into<String>) -> Self {
        MetricsSink { label: label.into(), runs: Vec::new() }
    }

    /// Record one execution's trace.
    pub fn record(&mut self, trace: ExecTrace) {
        self.runs.push(trace);
    }

    /// Mean wall-clock time per run, summed over all stages.
    pub fn mean_total(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        self.runs.iter().map(|t| t.total_time()).sum::<Duration>() / self.runs.len() as u32
    }

    /// Mean time per run spent in comm stages.
    pub fn mean_comm(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        self.runs
            .iter()
            .map(|t| {
                t.stages
                    .iter()
                    .filter(|s| s.kind == StageKind::Comm)
                    .map(|s| s.elapsed)
                    .sum::<Duration>()
            })
            .sum::<Duration>()
            / self.runs.len() as u32
    }

    /// Mean time-in-wait per run (blocked in exchange receives; see
    /// `ExecTrace::wait_ns`).
    pub fn mean_wait(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(
            self.runs.iter().map(|t| t.wait_ns).sum::<u64>() / self.runs.len() as u64,
        )
    }

    /// Total bytes sent to other ranks over all recorded runs.
    pub fn total_bytes(&self) -> u64 {
        self.runs.iter().map(|t| t.comm_bytes()).sum()
    }

    /// Total point-to-point messages sent over all recorded runs.
    pub fn total_messages(&self) -> u64 {
        self.runs.iter().map(|t| t.comm_messages()).sum()
    }

    /// Fraction of recorded runs whose plan was served from a plan cache
    /// (`ExecTrace::plan_cache_hit`) — 1.0 for a steady-state SCF loop
    /// after its first iteration. 0.0 when no runs are recorded.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().filter(|t| t.plan_cache_hit).count() as f64 / self.runs.len() as f64
    }

    /// Workspace growth summed over all recorded runs
    /// (`ExecTrace::alloc_bytes`) — 0 once every plan involved has reached
    /// its high-water mark.
    pub fn total_alloc_bytes(&self) -> u64 {
        self.runs.iter().map(|t| t.alloc_bytes).sum()
    }

    /// Measured local compute rate over the runs (flops/s), for calibrating
    /// the performance model.
    pub fn measured_flop_rate(&self) -> f64 {
        let mut flops = 0.0;
        let mut secs = 0.0;
        for t in &self.runs {
            for s in &t.stages {
                if s.kind == StageKind::Compute {
                    flops += s.flops;
                    secs += s.elapsed.as_secs_f64();
                }
            }
        }
        if secs > 0.0 {
            flops / secs
        } else {
            0.0
        }
    }

    /// One human-readable table row: label, mean total/comm time, wire
    /// bytes and message count.
    pub fn one_line(&self) -> String {
        format!(
            "{:<34} {:>12?} total  {:>12?} comm  {:>12} B  {:>8} msgs",
            self.label,
            self.mean_total(),
            self.mean_comm(),
            self.total_bytes(),
            self.total_messages()
        )
    }

    /// JSON record for machine-readable bench output.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("label".to_string(), Json::Str(self.label.clone()));
        obj.insert("runs".to_string(), Json::Num(self.runs.len() as f64));
        obj.insert(
            "mean_total_s".to_string(),
            Json::Num(self.mean_total().as_secs_f64()),
        );
        obj.insert("mean_comm_s".to_string(), Json::Num(self.mean_comm().as_secs_f64()));
        obj.insert("mean_wait_s".to_string(), Json::Num(self.mean_wait().as_secs_f64()));
        obj.insert("bytes".to_string(), Json::Num(self.total_bytes() as f64));
        obj.insert("messages".to_string(), Json::Num(self.total_messages() as f64));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::plan::stages::StageKind;

    fn trace(ms: u64, bytes: u64) -> ExecTrace {
        let mut t = ExecTrace::default();
        t.push("fft", StageKind::Compute, Duration::from_millis(ms), 0, 0, 1e6);
        t.push("a2a", StageKind::Comm, Duration::from_millis(ms), bytes, 1, 0.0);
        t
    }

    #[test]
    fn aggregates() {
        let mut m = MetricsSink::new("test");
        m.record(trace(10, 100));
        m.record(trace(20, 200));
        assert_eq!(m.total_bytes(), 300);
        assert_eq!(m.total_messages(), 2);
        assert_eq!(m.mean_comm(), Duration::from_millis(15));
        assert!(m.measured_flop_rate() > 0.0);
    }

    #[test]
    fn cache_and_alloc_aggregates() {
        let mut m = MetricsSink::new("scf");
        assert_eq!(m.cache_hit_rate(), 0.0);
        let mut cold = trace(10, 100);
        cold.alloc_bytes = 4096;
        m.record(cold);
        let mut hot = trace(10, 100);
        hot.plan_cache_hit = true;
        m.record(hot.clone());
        m.record(hot);
        assert!((m.cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.total_alloc_bytes(), 4096);
    }

    #[test]
    fn json_round_trip() {
        let mut m = MetricsSink::new("x");
        m.record(trace(5, 50));
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bytes").unwrap().as_f64(), Some(50.0));
    }
}
