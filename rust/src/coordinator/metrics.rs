//! Metrics collection: aggregate [`ExecTrace`]s into table rows / CSV /
//! JSON for the benches and EXPERIMENTS.md.
//!
//! Atomics audit: this sink is deliberately single-threaded — traces are
//! merged across ranks *before* they arrive here (see
//! [`ExecTrace::critical_path`]), so it holds plain fields and no atomics.
//! The crate-wide atomic-ordering conventions live in
//! [`lint`](crate::lint) and `docs/ARCHITECTURE.md`.

use std::time::Duration;

use crate::fftb::plan::{ExecTrace, StageKind};
use crate::util::json::Json;

/// Samples kept per latency reservoir. 256 windows the most recent
/// behaviour of a long-lived service; the ring overwrite keeps the record
/// path O(1) and allocation-free after construction.
const RESERVOIR_CAP: usize = 256;

/// Fixed-size latency reservoir: the last [`RESERVOIR_CAP`] samples in a
/// preallocated ring. Recording never allocates (the buffer's full capacity
/// is reserved up front); percentile queries sort a scratch copy, so they
/// are the (cheap, off-path) side that pays.
#[derive(Clone, Debug)]
pub struct LatencyReservoir {
    /// Sample ring (nanoseconds), preallocated to `RESERVOIR_CAP`.
    samples: Vec<u64>,
    /// Next overwrite position once the ring is full.
    next: usize,
    /// Total samples ever recorded (can exceed the ring size).
    count: u64,
}

impl Default for LatencyReservoir {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyReservoir {
    /// An empty reservoir with its full ring capacity preallocated.
    pub fn new() -> Self {
        LatencyReservoir { samples: Vec::with_capacity(RESERVOIR_CAP), next: 0, count: 0 }
    }

    /// Record one latency sample. Zero-alloc: the ring was preallocated at
    /// construction, so this is a push-within-capacity or an overwrite.
    pub fn record(&mut self, ns: u64) {
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(ns);
        } else {
            self.samples[self.next] = ns;
            self.next = (self.next + 1) % RESERVOIR_CAP;
        }
        self.count += 1;
    }

    /// Total samples ever recorded (not capped by the ring size).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The `p`-th percentile (0..=100, nearest-rank on the retained
    /// window), or `None` before any sample arrives.
    pub fn percentile(&self, p: f64) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        Some(Duration::from_nanos(sorted[idx.min(sorted.len() - 1)]))
    }
}

/// Per-tenant request accounting: latency percentiles over a fixed-size
/// reservoir plus throughput counters. Lives inside [`MetricsSink`]; the
/// record path ([`TenantMetrics::record`]) is allocation-free.
#[derive(Clone, Debug)]
pub struct TenantMetrics {
    /// Tenant label (as registered with the service).
    pub label: String,
    /// Requests completed so far.
    pub requests: u64,
    /// Payload bytes moved through completed requests.
    pub bytes: u64,
    /// Submit-to-completion latency reservoir.
    pub latency: LatencyReservoir,
}

impl TenantMetrics {
    /// Empty accounting for the tenant named `label`.
    pub fn new(label: impl Into<String>) -> Self {
        TenantMetrics {
            label: label.into(),
            requests: 0,
            bytes: 0,
            latency: LatencyReservoir::new(),
        }
    }

    /// Record one completed request: its submit-to-completion latency and
    /// payload size. Zero-alloc (counter bumps + ring write).
    pub fn record(&mut self, latency_ns: u64, bytes: u64) {
        self.requests += 1;
        self.bytes += bytes;
        self.latency.record(latency_ns);
    }

    /// Median latency over the retained window.
    pub fn p50(&self) -> Option<Duration> {
        self.latency.percentile(50.0)
    }

    /// 95th-percentile latency over the retained window.
    pub fn p95(&self) -> Option<Duration> {
        self.latency.percentile(95.0)
    }

    /// 99th-percentile latency over the retained window.
    pub fn p99(&self) -> Option<Duration> {
        self.latency.percentile(99.0)
    }

    /// One human-readable row: label, request/byte counters, percentiles.
    pub fn one_line(&self) -> String {
        let d = |p: Option<Duration>| p.map_or("-".to_string(), |d| format!("{d:?}"));
        format!(
            "{:<24} {:>8} reqs {:>12} B  p50 {:>10} p95 {:>10} p99 {:>10}",
            self.label,
            self.requests,
            self.bytes,
            d(self.p50()),
            d(self.p95()),
            d(self.p99())
        )
    }
}

/// Aggregated view of one experiment configuration.
#[derive(Clone, Debug)]
pub struct MetricsSink {
    /// Configuration label printed in tables and JSON records.
    pub label: String,
    /// Per-run traces recorded so far, in call order.
    pub runs: Vec<ExecTrace>,
    /// Per-tenant accounting (service layer); indexed by the id handed out
    /// by [`MetricsSink::register_tenant`].
    pub tenants: Vec<TenantMetrics>,
}

impl MetricsSink {
    /// An empty sink for the configuration named `label`.
    pub fn new(label: impl Into<String>) -> Self {
        MetricsSink { label: label.into(), runs: Vec::new(), tenants: Vec::new() }
    }

    /// Record one execution's trace.
    pub fn record(&mut self, trace: ExecTrace) {
        self.runs.push(trace);
    }

    /// Register a tenant for per-tenant accounting; returns its index for
    /// [`MetricsSink::record_tenant`].
    pub fn register_tenant(&mut self, label: impl Into<String>) -> usize {
        self.tenants.push(TenantMetrics::new(label));
        self.tenants.len() - 1
    }

    /// Record one completed request of tenant `idx` (zero-alloc; see
    /// [`TenantMetrics::record`]).
    pub fn record_tenant(&mut self, idx: usize, latency_ns: u64, bytes: u64) {
        self.tenants[idx].record(latency_ns, bytes);
    }

    /// Per-tenant accounting rows registered so far.
    pub fn tenant_metrics(&self) -> &[TenantMetrics] {
        &self.tenants
    }

    /// Mean wall-clock time per run, summed over all stages.
    pub fn mean_total(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        self.runs.iter().map(|t| t.total_time()).sum::<Duration>() / self.runs.len() as u32
    }

    /// Mean time per run spent in comm stages.
    pub fn mean_comm(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        self.runs
            .iter()
            .map(|t| {
                t.stages
                    .iter()
                    .filter(|s| s.kind == StageKind::Comm)
                    .map(|s| s.elapsed)
                    .sum::<Duration>()
            })
            .sum::<Duration>()
            / self.runs.len() as u32
    }

    /// Mean time-in-wait per run (blocked in exchange receives; see
    /// `ExecTrace::wait_ns`).
    pub fn mean_wait(&self) -> Duration {
        if self.runs.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(
            self.runs.iter().map(|t| t.wait_ns).sum::<u64>() / self.runs.len() as u64,
        )
    }

    /// Total bytes sent to other ranks over all recorded runs.
    pub fn total_bytes(&self) -> u64 {
        self.runs.iter().map(|t| t.comm_bytes()).sum()
    }

    /// Total point-to-point messages sent over all recorded runs.
    pub fn total_messages(&self) -> u64 {
        self.runs.iter().map(|t| t.comm_messages()).sum()
    }

    /// Fraction of recorded runs whose plan was served from a plan cache
    /// (`ExecTrace::plan_cache_hit`) — 1.0 for a steady-state SCF loop
    /// after its first iteration. 0.0 when no runs are recorded.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.runs.is_empty() {
            return 0.0;
        }
        self.runs.iter().filter(|t| t.plan_cache_hit).count() as f64 / self.runs.len() as f64
    }

    /// Workspace growth summed over all recorded runs
    /// (`ExecTrace::alloc_bytes`) — 0 once every plan involved has reached
    /// its high-water mark.
    pub fn total_alloc_bytes(&self) -> u64 {
        self.runs.iter().map(|t| t.alloc_bytes).sum()
    }

    /// Measured local compute rate over the runs (flops/s), for calibrating
    /// the performance model.
    pub fn measured_flop_rate(&self) -> f64 {
        let mut flops = 0.0;
        let mut secs = 0.0;
        for t in &self.runs {
            for s in &t.stages {
                if s.kind == StageKind::Compute {
                    flops += s.flops;
                    secs += s.elapsed.as_secs_f64();
                }
            }
        }
        if secs > 0.0 {
            flops / secs
        } else {
            0.0
        }
    }

    /// One human-readable table row: label, mean total/comm time, wire
    /// bytes and message count.
    pub fn one_line(&self) -> String {
        format!(
            "{:<34} {:>12?} total  {:>12?} comm  {:>12} B  {:>8} msgs",
            self.label,
            self.mean_total(),
            self.mean_comm(),
            self.total_bytes(),
            self.total_messages()
        )
    }

    /// JSON record for machine-readable bench output.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("label".to_string(), Json::Str(self.label.clone()));
        obj.insert("runs".to_string(), Json::Num(self.runs.len() as f64));
        obj.insert(
            "mean_total_s".to_string(),
            Json::Num(self.mean_total().as_secs_f64()),
        );
        obj.insert("mean_comm_s".to_string(), Json::Num(self.mean_comm().as_secs_f64()));
        obj.insert("mean_wait_s".to_string(), Json::Num(self.mean_wait().as_secs_f64()));
        obj.insert("bytes".to_string(), Json::Num(self.total_bytes() as f64));
        obj.insert("messages".to_string(), Json::Num(self.total_messages() as f64));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fftb::plan::stages::StageKind;

    fn trace(ms: u64, bytes: u64) -> ExecTrace {
        let mut t = ExecTrace::default();
        t.push("fft", StageKind::Compute, Duration::from_millis(ms), 0, 0, 1e6);
        t.push("a2a", StageKind::Comm, Duration::from_millis(ms), bytes, 1, 0.0);
        t
    }

    #[test]
    fn aggregates() {
        let mut m = MetricsSink::new("test");
        m.record(trace(10, 100));
        m.record(trace(20, 200));
        assert_eq!(m.total_bytes(), 300);
        assert_eq!(m.total_messages(), 2);
        assert_eq!(m.mean_comm(), Duration::from_millis(15));
        assert!(m.measured_flop_rate() > 0.0);
    }

    #[test]
    fn cache_and_alloc_aggregates() {
        let mut m = MetricsSink::new("scf");
        assert_eq!(m.cache_hit_rate(), 0.0);
        let mut cold = trace(10, 100);
        cold.alloc_bytes = 4096;
        m.record(cold);
        let mut hot = trace(10, 100);
        hot.plan_cache_hit = true;
        m.record(hot.clone());
        m.record(hot);
        assert!((m.cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.total_alloc_bytes(), 4096);
    }

    #[test]
    fn reservoir_percentiles_are_nearest_rank() {
        let mut r = LatencyReservoir::new();
        assert!(r.percentile(50.0).is_none());
        for ns in 1..=100u64 {
            r.record(ns);
        }
        assert_eq!(r.count(), 100);
        assert_eq!(r.percentile(50.0), Some(Duration::from_nanos(51)));
        assert_eq!(r.percentile(95.0), Some(Duration::from_nanos(95)));
        assert_eq!(r.percentile(99.0), Some(Duration::from_nanos(99)));
        assert_eq!(r.percentile(0.0), Some(Duration::from_nanos(1)));
        assert_eq!(r.percentile(100.0), Some(Duration::from_nanos(100)));
    }

    #[test]
    fn reservoir_ring_overwrites_oldest_without_allocating() {
        let mut r = LatencyReservoir::new();
        let cap0 = r.samples.capacity();
        for ns in 0..(RESERVOIR_CAP as u64 * 2) {
            r.record(ns);
        }
        assert_eq!(r.samples.capacity(), cap0, "ring must never grow past its preallocation");
        assert_eq!(r.samples.len(), RESERVOIR_CAP);
        assert_eq!(r.count(), RESERVOIR_CAP as u64 * 2);
        // Only the newest window survives.
        assert!(r.samples.iter().all(|&ns| ns >= RESERVOIR_CAP as u64));
    }

    #[test]
    fn tenant_metrics_accumulate_per_tenant() {
        let mut m = MetricsSink::new("service");
        let a = m.register_tenant("scf-a");
        let b = m.register_tenant("scf-b");
        for i in 0..10u64 {
            m.record_tenant(a, 1000 + i, 64);
        }
        m.record_tenant(b, 5000, 128);
        assert_eq!(m.tenant_metrics()[a].requests, 10);
        assert_eq!(m.tenant_metrics()[a].bytes, 640);
        assert_eq!(m.tenant_metrics()[b].requests, 1);
        assert!(m.tenant_metrics()[a].p50().unwrap() < m.tenant_metrics()[b].p50().unwrap());
        assert!(m.tenant_metrics()[a].one_line().contains("scf-a"));
    }

    #[test]
    fn json_round_trip() {
        let mut m = MetricsSink::new("x");
        m.record(trace(5, 50));
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bytes").unwrap().as_f64(), Some(50.0));
    }
}
