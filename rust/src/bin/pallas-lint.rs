//! `pallas-lint`: the repo's custom static-analysis pass (see the
//! `fftb::lint` module).
//!
//! Usage:
//!
//! ```text
//! cargo run --release --quiet --bin pallas-lint            # lint rust/src
//! cargo run --release --quiet --bin pallas-lint -- <paths> # lint paths
//! ```
//!
//! Diagnostics are machine-readable, one per line:
//! `file:line: [rule] message`. Exit status is 0 when clean, 1 when there
//! are findings, 2 on I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let roots: Vec<PathBuf> = {
        let args: Vec<PathBuf> =
            std::env::args().skip(1).filter(|a| !a.starts_with('-')).map(PathBuf::from).collect();
        if args.is_empty() {
            vec![PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))]
        } else {
            args
        }
    };

    let mut files = 0usize;
    let mut findings = Vec::new();
    for root in &roots {
        match fftb::lint::lint_tree(root) {
            Ok(report) => {
                files += report.files;
                findings.extend(report.diagnostics);
            }
            Err(e) => {
                eprintln!("pallas-lint: {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    }

    for d in &findings {
        println!("{d}");
    }
    if findings.is_empty() {
        eprintln!("pallas-lint: {files} file(s) clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("pallas-lint: {} finding(s) across {files} file(s)", findings.len());
        ExitCode::FAILURE
    }
}
