//! Bench: §4.2 batching ablation — one aggregated alltoall per stage vs a
//! loop of per-band exchanges.
//!
//! Live: messages, bytes and time on the in-process testbed. Modeled: the
//! same comparison priced on Perlmutter at paper scale, where the latency
//! term (nb * (p-1) * alpha) is what separates the dark- and light-blue
//! lines of Fig. 9.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{NonBatchedLoop, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{project, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn live() {
    println!("== live: cube 32^3, nb=8 ==");
    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "p", "msgs-b", "msgs-nb", "bytes-b", "bytes-nb", "time-b", "time-nb"
    );
    let n = 32usize;
    let nb = 8usize;
    for p in [2usize, 4, 8] {
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let input = phased(batched.input_len(), 1);

            let mut mb = (0u64, 0u64);
            let tb = bench(2, 5, || {
                let (_, tr) = batched.forward(&backend, input.clone());
                mb = (tr.comm_messages(), tr.comm_bytes());
            });
            let mut ml = (0u64, 0u64);
            let tl = bench(1, 3, || {
                let (_, tr) = looped.forward(&backend, input.clone());
                ml = (tr.comm_messages(), tr.comm_bytes());
            });
            (mb, ml, tb.mean(), tl.mean())
        });
        let r = &rows[0];
        println!(
            "{p:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
            r.0 .0,
            r.1 .0,
            r.0 .1,
            r.1 .1,
            fmt_duration(rows.iter().map(|r| r.2).max().unwrap()),
            fmt_duration(rows.iter().map(|r| r.3).max().unwrap()),
        );
        // Invariants: same bytes, nb x messages.
        assert_eq!(r.0 .1, r.1 .1, "batching must not change total bytes");
        assert_eq!(r.1 .0, nb as u64 * r.0 .0, "loop sends nb x the messages");
    }
}

fn modeled() {
    println!();
    println!("== modeled at paper scale (256^3, nb=256, perlmutter-a100) ==");
    println!("{:>5} {:>12} {:>12} {:>8}", "p", "batched", "non-batched", "ratio");
    let n = 256usize;
    let spec = SphereSpec::new([n, n, n], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [n, n, n], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();
    let mut prev_ratio = 0.0;
    for p in [16usize, 64, 256, 1024] {
        let tb = project(Variant::Slab1dBatched, &w, p, &m);
        let tn = project(Variant::Slab1dNonBatched, &w, p, &m);
        let ratio = tn / tb;
        println!("{p:>5} {:>10.2}ms {:>10.2}ms {ratio:>7.1}x", tb * 1e3, tn * 1e3);
        assert!(ratio > 1.0, "non-batched must lose at p={p}");
        if p >= 64 {
            assert!(ratio >= prev_ratio * 0.8, "gap should widen (or hold) with p");
        }
        prev_ratio = ratio;
    }
}

fn main() {
    live();
    modeled();
    println!("batching_ablation bench done");
}
