//! Bench: §4.2 batching ablation — one aggregated alltoall per stage vs a
//! loop of per-band exchanges.
//!
//! Live: messages, bytes and time on the in-process testbed. Modeled: the
//! same comparison priced on Perlmutter at paper scale, where the latency
//! term (nb * (p-1) * alpha) is what separates the dark- and light-blue
//! lines of Fig. 9.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::coordinator::{BatchingDriver, TransformJob};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::{phased, scatter_cube_x};
use fftb::fftb::plan::{NonBatchedLoop, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{project, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn live() {
    println!("== live: cube 32^3, nb=8 ==");
    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "p", "msgs-b", "msgs-nb", "bytes-b", "bytes-nb", "time-b", "time-nb"
    );
    let n = 32usize;
    let nb = 8usize;
    for p in [2usize, 4, 8] {
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let input = phased(batched.input_len(), 1);

            let mut mb = (0u64, 0u64);
            let tb = bench(2, 5, || {
                let (_, tr) = batched.forward(&backend, input.clone());
                mb = (tr.comm_messages(), tr.comm_bytes());
            });
            let mut ml = (0u64, 0u64);
            let tl = bench(1, 3, || {
                let (_, tr) = looped.forward(&backend, input.clone());
                ml = (tr.comm_messages(), tr.comm_bytes());
            });
            (mb, ml, tb.mean(), tl.mean())
        });
        let r = &rows[0];
        println!(
            "{p:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
            r.0 .0,
            r.1 .0,
            r.0 .1,
            r.1 .1,
            fmt_duration(rows.iter().map(|r| r.2).max().unwrap()),
            fmt_duration(rows.iter().map(|r| r.3).max().unwrap()),
        );
        // Invariants: same bytes, nb x messages.
        assert_eq!(r.0 .1, r.1 .1, "batching must not change total bytes");
        assert_eq!(r.1 .0, nb as u64 * r.0 .0, "loop sends nb x the messages");
    }
}

fn modeled() {
    println!();
    println!("== modeled at paper scale (256^3, nb=256, perlmutter-a100) ==");
    println!("{:>5} {:>12} {:>12} {:>8}", "p", "batched", "non-batched", "ratio");
    let n = 256usize;
    let spec = SphereSpec::new([n, n, n], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [n, n, n], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();
    let mut prev_ratio = 0.0;
    for p in [16usize, 64, 256, 1024] {
        let tb = project(Variant::Slab1dBatched, &w, p, &m);
        let tn = project(Variant::Slab1dNonBatched, &w, p, &m);
        let ratio = tn / tb;
        println!("{p:>5} {:>10.2}ms {:>10.2}ms {ratio:>7.1}x", tb * 1e3, tn * 1e3);
        assert!(ratio > 1.0, "non-batched must lose at p={p}");
        if p >= 64 {
            assert!(ratio >= prev_ratio * 0.8, "gap should widen (or hold) with p");
        }
        prev_ratio = ratio;
    }
}

/// Cached vs uncached flush: the driver's plan cache means only the first
/// flush of a given batch size plans (and warms a workspace); every later
/// flush reuses both. Prints the first-flush and steady-state flush times
/// and asserts the cache contract (`plan_cache_hit`, zero steady-state
/// workspace growth).
fn cached_flush() {
    println!();
    println!("== cached vs uncached flush (driver plan cache) ==");
    let n = 32usize;
    let nb = 8usize;
    let p = 4usize;
    let rounds = 5usize;
    let rows = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let mut driver = BatchingDriver::new([n, n, n], Arc::clone(&grid));
        let bands: Vec<_> = (0..nb)
            .map(|b| {
                let g = phased(n * n * n, b as u64);
                scatter_cube_x(&g, 1, [n, n, n], p, grid.rank())
            })
            .collect();
        let mut first = std::time::Duration::ZERO;
        let mut warm_best = std::time::Duration::MAX;
        for round in 0..rounds {
            for (i, b) in bands.iter().enumerate() {
                driver.submit(TransformJob {
                    id: i as u64,
                    data: b.clone(),
                    dir: Direction::Forward,
                });
            }
            let t0 = std::time::Instant::now();
            driver.flush(&backend, Direction::Forward);
            let dt = t0.elapsed();
            let tr = driver.drain_traces().pop().unwrap();
            if round == 0 {
                first = dt;
                assert!(!tr.plan_cache_hit, "first flush must plan");
            } else {
                warm_best = warm_best.min(dt);
                assert!(tr.plan_cache_hit, "flush {round} must hit the plan cache");
                assert_eq!(tr.alloc_bytes, 0, "steady-state flush must not allocate");
            }
            driver.drain_completed();
        }
        let (hits, misses) = driver.plan_cache_stats();
        assert_eq!((hits, misses), ((rounds - 1) as u64, 1));
        (first, warm_best)
    });
    let first = rows.iter().map(|r| r.0).max().unwrap();
    let warm = rows.iter().map(|r| r.1).max().unwrap();
    println!(
        "cube {n}^3, nb={nb}, p={p}: first flush {} (plans + cold workspaces), \
         steady flush {}",
        fmt_duration(first),
        fmt_duration(warm)
    );
}

fn main() {
    live();
    modeled();
    cached_flush();
    println!("batching_ablation bench done");
}
