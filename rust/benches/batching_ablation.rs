//! Bench: §4.2 batching ablation — one aggregated alltoall per stage vs a
//! loop of per-band exchanges.
//!
//! Live: messages, bytes and time on the in-process testbed. Modeled: the
//! same comparison priced on Perlmutter at paper scale, where the latency
//! term (nb * (p-1) * alpha) is what separates the dark- and light-blue
//! lines of Fig. 9.
//!
//! Also ablated here: the driver's two-deep software pipeline (worker-off
//! depth 1 vs worker-on depth 2) — with the worker thread, flush k's
//! de-interleave tail runs concurrently with flush k+1's exchange.
//! Reported: slowest-rank wall time per mode and the overlapped tail
//! nanoseconds (`ExecTrace::pipeline_overlap_ns`); bit-identity of the
//! two depths is asserted.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::coordinator::{BatchingDriver, TransformJob};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::{phased, scatter_cube_x};
use fftb::fftb::plan::{NonBatchedLoop, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{project, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn live() {
    println!("== live: cube 32^3, nb=8 ==");
    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "p", "msgs-b", "msgs-nb", "bytes-b", "bytes-nb", "time-b", "time-nb"
    );
    let n = 32usize;
    let nb = 8usize;
    for p in [2usize, 4, 8] {
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let input = phased(batched.input_len(), 1);

            let mut mb = (0u64, 0u64);
            let tb = bench(2, 5, || {
                let (_, tr) = batched.forward(&backend, input.clone());
                mb = (tr.comm_messages(), tr.comm_bytes());
            });
            let mut ml = (0u64, 0u64);
            let tl = bench(1, 3, || {
                let (_, tr) = looped.forward(&backend, input.clone());
                ml = (tr.comm_messages(), tr.comm_bytes());
            });
            (mb, ml, tb.mean(), tl.mean())
        });
        let r = &rows[0];
        println!(
            "{p:>4} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
            r.0 .0,
            r.1 .0,
            r.0 .1,
            r.1 .1,
            fmt_duration(rows.iter().map(|r| r.2).max().unwrap()),
            fmt_duration(rows.iter().map(|r| r.3).max().unwrap()),
        );
        // Invariants: same bytes, nb x messages.
        assert_eq!(r.0 .1, r.1 .1, "batching must not change total bytes");
        assert_eq!(r.1 .0, nb as u64 * r.0 .0, "loop sends nb x the messages");
    }
}

fn modeled() {
    println!();
    println!("== modeled at paper scale (256^3, nb=256, perlmutter-a100) ==");
    println!("{:>5} {:>12} {:>12} {:>8}", "p", "batched", "non-batched", "ratio");
    let n = 256usize;
    let spec = SphereSpec::new([n, n, n], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [n, n, n], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();
    let mut prev_ratio = 0.0;
    for p in [16usize, 64, 256, 1024] {
        let tb = project(Variant::Slab1dBatched, &w, p, &m);
        let tn = project(Variant::Slab1dNonBatched, &w, p, &m);
        let ratio = tn / tb;
        println!("{p:>5} {:>10.2}ms {:>10.2}ms {ratio:>7.1}x", tb * 1e3, tn * 1e3);
        assert!(ratio > 1.0, "non-batched must lose at p={p}");
        if p >= 64 {
            assert!(ratio >= prev_ratio * 0.8, "gap should widen (or hold) with p");
        }
        prev_ratio = ratio;
    }
}

/// Cached vs uncached flush: the driver's plan cache means only the first
/// flush of a given batch size plans (and warms a workspace); every later
/// flush reuses both. Prints the first-flush and steady-state flush times
/// and asserts the cache contract (`plan_cache_hit`, zero steady-state
/// workspace growth).
fn cached_flush() {
    println!();
    println!("== cached vs uncached flush (driver plan cache) ==");
    let n = 32usize;
    let nb = 8usize;
    let p = 4usize;
    let rounds = 5usize;
    let rows = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let mut driver = BatchingDriver::new([n, n, n], Arc::clone(&grid));
        let bands: Vec<_> = (0..nb)
            .map(|b| {
                let g = phased(n * n * n, b as u64);
                scatter_cube_x(&g, 1, [n, n, n], p, grid.rank())
            })
            .collect();
        let mut first = std::time::Duration::ZERO;
        let mut warm_best = std::time::Duration::MAX;
        for round in 0..rounds {
            for (i, b) in bands.iter().enumerate() {
                driver.submit(TransformJob {
                    id: i as u64,
                    data: b.clone(),
                    dir: Direction::Forward,
                });
            }
            let t0 = std::time::Instant::now();
            driver.flush(&backend, Direction::Forward);
            let dt = t0.elapsed();
            let tr = driver.drain_traces().pop().unwrap();
            if round == 0 {
                first = dt;
                assert!(!tr.plan_cache_hit, "first flush must plan");
            } else {
                warm_best = warm_best.min(dt);
                assert!(tr.plan_cache_hit, "flush {round} must hit the plan cache");
                assert_eq!(tr.alloc_bytes, 0, "steady-state flush must not allocate");
            }
            driver.drain_completed();
        }
        let (hits, misses) = driver.plan_cache_stats();
        assert_eq!((hits, misses), ((rounds - 1) as u64, 1));
        (first, warm_best)
    });
    let first = rows.iter().map(|r| r.0).max().unwrap();
    let warm = rows.iter().map(|r| r.1).max().unwrap();
    println!(
        "cube {n}^3, nb={nb}, p={p}: first flush {} (plans + cold workspaces), \
         steady flush {}",
        fmt_duration(first),
        fmt_duration(warm)
    );
}

/// Pipeline depth 1 (worker off) vs depth 2 (worker on): a run of flushes
/// with no intermediate drains, so every depth-2 flush's exchange overlaps
/// the previous flush's de-interleave tail on the worker thread.
fn pipeline_ablation() {
    println!();
    println!("== pipeline depth 1 vs 2 (driver worker thread) ==");
    let n = 32usize;
    let nb = 8usize;
    let p = 4usize;
    let rounds = 5usize;
    let run = |depth: usize| {
        run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let mut driver =
                BatchingDriver::new([n, n, n], Arc::clone(&grid)).with_pipeline_depth(depth);
            let bands: Vec<_> = (0..nb)
                .map(|b| {
                    let g = phased(n * n * n, b as u64);
                    scatter_cube_x(&g, 1, [n, n, n], p, grid.rank())
                })
                .collect();
            let t0 = std::time::Instant::now();
            for round in 0..rounds {
                for (i, b) in bands.iter().enumerate() {
                    driver.submit(TransformJob {
                        id: (round * nb + i) as u64,
                        data: b.clone(),
                        dir: Direction::Forward,
                    });
                }
                driver.flush(&backend, Direction::Forward);
            }
            let got = driver.drain_completed();
            let wall = t0.elapsed();
            let overlap: u64 =
                driver.drain_traces().iter().map(|t| t.pipeline_overlap_ns).sum();
            (wall, overlap, got)
        })
    };
    let d1 = run(1);
    let d2 = run(2);
    for (r, ((_, ov1, g1), (_, _, g2))) in d1.iter().zip(&d2).enumerate() {
        assert_eq!(*ov1, 0, "depth 1 must report no pipeline overlap");
        assert_eq!(g1.len(), g2.len(), "rank {r}: result count differs across depths");
        for ((i1, v1), (i2, v2)) in g1.iter().zip(g2) {
            assert_eq!(i1, i2, "rank {r}: pipelined flushes must stay FIFO");
            for (a, b) in v1.iter().zip(v2) {
                assert_eq!(a.re.to_bits(), b.re.to_bits(), "rank {r}: depth 2 diverged");
                assert_eq!(a.im.to_bits(), b.im.to_bits(), "rank {r}: depth 2 diverged");
            }
        }
    }
    let w1 = d1.iter().map(|r| r.0).max().unwrap();
    let w2 = d2.iter().map(|r| r.0).max().unwrap();
    let ov = d2.iter().map(|r| r.1).max().unwrap();
    println!(
        "cube {n}^3, nb={nb}, p={p}, {rounds} rounds: depth 1 {}, depth 2 {} \
         (overlapped tail {} on the slowest rank)",
        fmt_duration(w1),
        fmt_duration(w2),
        fmt_duration(std::time::Duration::from_nanos(ov))
    );
}

fn main() {
    live();
    modeled();
    cached_flush();
    pipeline_ablation();
    println!("batching_ablation bench done");
}
