//! Micro-bench: the pairwise exchange disciplines side by side.
//!
//! Section 1 — serial vs overlapped: for each (p, payload) cell the same
//! flat complex alltoallv runs with the fully serial schedule (round s
//! blocks on its receive before round s+1's send is posted) and with the
//! windowed overlapped pipeline (window = p-1: all receives pre-posted,
//! sends run ahead of the waits), under a deterministic per-rank start
//! skew modeling imbalanced pack times — the regime where serial rounds
//! convoy.
//!
//! Section 2 — fused vs pre-packed: the full pack → exchange → unpack of
//! a slab-style split/merge runs once as the monolithic three-phase path
//! (`split_dim_into`, flat windowed exchange, `merge_dim_from`) and once
//! through the fused engine (`SplitMergeKernel` packing each destination
//! into its wire buffer as its round posts, unpacking as each wait
//! completes). Reported: slowest-rank wall time per full exchange and the
//! fused path's overlapped pack+unpack nanoseconds — the work the
//! monolithic path serializes before/after the wire.
//!
//! Section 3 — worker-off vs worker-on: the same fused split/merge with
//! the exchange's helper worker thread disabled and enabled
//! (`CommTuning::with_worker`). With the worker, pack/unpack run on the
//! helper *while* the communicating thread is blocked in waits, instead
//! of between them. Reported: slowest-rank wall time per mode and the
//! helper's busy nanoseconds; bit-identity of the two modes is asserted.
//!
//! Section 4 — c2c vs r2c sphere exchange: the same plane-wave sphere
//! forward through the complex plan and the Hermitian half-spectrum plan
//! (`RealPlaneWavePlan`). The r2c kernels move only the `nz/2 + 1`
//! Hermitian-unique z bins, so the fused exchange carries
//! `(nz/2 + 1)/nz` of the c2c wire bytes — the byte columns are exact
//! accounting (asserted < 0.6x summed across ranks), the time columns
//! are live means.
//!
//! Reported per discipline: slowest-rank wall time per exchange and
//! slowest-rank `ExecTrace::wait_ns` per exchange (time blocked in
//! receive waits). Expected shape: the overlapped schedule shows lower
//! time-in-wait at p >= 4, because a late rank's sends reach its partners
//! in one burst instead of one round at a time.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fftb::comm::alltoall::{alltoallv_complex_flat_serial, alltoallv_complex_flat_tuned};
use fftb::comm::{barrier, run_world, CommTuning};
use fftb::fft::complex::{Complex, ZERO};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::{cyclic, ProcGrid};
use fftb::fftb::plan::redistribute::{merge_dim_from, split_dim_into, volume};
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{
    fused_exchange, A2aSchedule, ExecTrace, PlaneWavePlan, RealPlaneWavePlan, SplitMergeKernel,
};
use fftb::fftb::sphere::{SphereKind, SphereSpec};

const WARMUP: usize = 5;
const ITERS: usize = 30;
/// Per-rank start stagger in microseconds (rank r enters r*SKEW_US late).
const SKEW_US: u64 = 100;

fn busy_wait_us(us: u64) {
    let t0 = Instant::now();
    while (t0.elapsed().as_micros() as u64) < us {
        std::hint::spin_loop();
    }
}

fn fmt_us(d: Duration) -> String {
    format!("{:.1}us", d.as_secs_f64() * 1e6)
}

/// Fused vs pre-packed full exchange (pack + wire + unpack) on a
/// slab-style split/merge, window 2, with the same per-rank start skew.
fn fused_section() {
    println!();
    println!("fused vs pre-packed exchange (slab split/merge, window 2), skew {SKEW_US}us/rank");
    println!(
        "{:>4} {:>7} | {:>11} | {:>11} {:>14} | {}",
        "p", "n", "pre-packed", "fused", "fused-overlap", "note"
    );
    for p in [2usize, 4, 8] {
        for n in [16usize, 32] {
            let (nb, ny) = (2usize, n);
            let rows = run_world(p, move |comm| {
                let me = comm.rank();
                let lxc = cyclic::local_count(n, p, me);
                let lzc = cyclic::local_count(n, p, me);
                let sh_in = [nb, lxc, ny, n];
                let sh_out = [nb, n, ny, lzc];
                let sched = A2aSchedule::for_split_merge(sh_in, 3, sh_out, 1, p, me);
                let data: Vec<Complex> =
                    (0..volume(sh_in)).map(|i| Complex::new(i as f64, me as f64)).collect();
                let tuning = CommTuning::with_window(2);

                // Pre-packed: monolithic pack -> flat exchange -> merge.
                let mut send = vec![ZERO; sched.send_total()];
                let mut recv = vec![ZERO; sched.recv_total()];
                let mut out = vec![ZERO; volume(sh_out)];
                let mut t_pre = Duration::ZERO;
                for it in 0..WARMUP + ITERS {
                    barrier(&comm);
                    busy_wait_us(me as u64 * SKEW_US);
                    let t0 = Instant::now();
                    split_dim_into(&data, sh_in, 3, p, &mut send, &sched.send_offs);
                    let _ = alltoallv_complex_flat_tuned(
                        &comm,
                        &send,
                        &sched.send_offs,
                        &mut recv,
                        &sched.recv_offs,
                        tuning,
                    );
                    merge_dim_from(&recv, &sched.recv_offs, sh_out, 1, p, &mut out);
                    if it >= WARMUP {
                        t_pre += t0.elapsed();
                    }
                }
                let want = out.clone();

                // Fused: per-destination kernels inside the windowed engine.
                let mut t_fused = Duration::ZERO;
                let mut overlap_ns = 0u64;
                for it in 0..WARMUP + ITERS {
                    barrier(&comm);
                    busy_wait_us(me as u64 * SKEW_US);
                    let t0 = Instant::now();
                    let c = {
                        let mut k =
                            SplitMergeKernel::new(&sched, &data, sh_in, 3, &mut out, sh_out, 1);
                        fused_exchange(&comm, &mut k, tuning)
                    };
                    if it >= WARMUP {
                        t_fused += t0.elapsed();
                        overlap_ns += c.pack_overlap_ns + c.unpack_overlap_ns;
                    }
                }
                assert_eq!(out, want, "fused exchange must be bit-identical");
                (t_pre / ITERS as u32, t_fused / ITERS as u32, overlap_ns / ITERS as u64)
            });
            let t_pre = rows.iter().map(|r| r.0).max().unwrap();
            let t_fused = rows.iter().map(|r| r.1).max().unwrap();
            let overlap = rows.iter().map(|r| r.2).max().unwrap();
            let note = if p >= 4 && t_fused > t_pre {
                "fused did not win (timing noise?)"
            } else {
                ""
            };
            println!(
                "{p:>4} {n:>6}^ | {:>11} | {:>11} {:>14} | {note}",
                fmt_us(t_pre),
                fmt_us(t_fused),
                fmt_us(Duration::from_nanos(overlap)),
            );
        }
    }
}

/// Worker-off vs worker-on fused exchange on the same slab split/merge,
/// window 2: the helper thread takes the pack/unpack movers off the
/// communicating thread's critical path.
fn worker_section() {
    println!();
    println!("worker-off vs worker-on exchange (slab split/merge, window 2), skew {SKEW_US}us/rank");
    println!(
        "{:>4} {:>7} | {:>11} | {:>11} {:>14} | {}",
        "p", "n", "worker-off", "worker-on", "worker-busy", "note"
    );
    for p in [2usize, 4, 8] {
        for n in [16usize, 32] {
            let (nb, ny) = (2usize, n);
            let rows = run_world(p, move |comm| {
                let me = comm.rank();
                let lxc = cyclic::local_count(n, p, me);
                let lzc = cyclic::local_count(n, p, me);
                let sh_in = [nb, lxc, ny, n];
                let sh_out = [nb, n, ny, lzc];
                let sched = A2aSchedule::for_split_merge(sh_in, 3, sh_out, 1, p, me);
                let data: Vec<Complex> =
                    (0..volume(sh_in)).map(|i| Complex::new(i as f64, me as f64)).collect();

                let mut bench_mode = |worker: bool| {
                    let tuning = CommTuning::with_window(2).with_worker(worker);
                    let mut out = vec![ZERO; volume(sh_out)];
                    let mut t = Duration::ZERO;
                    let mut busy = 0u64;
                    for it in 0..WARMUP + ITERS {
                        barrier(&comm);
                        busy_wait_us(me as u64 * SKEW_US);
                        let t0 = Instant::now();
                        let k =
                            SplitMergeKernel::new(&sched, &data, sh_in, 3, &mut out, sh_out, 1);
                        let c = k.exchange(&comm, tuning);
                        if it >= WARMUP {
                            t += t0.elapsed();
                            busy += c.worker_busy_ns;
                        }
                    }
                    (t / ITERS as u32, busy / ITERS as u64, out)
                };
                let (t_off, _, want) = bench_mode(false);
                let (t_on, busy, got) = bench_mode(true);
                for (a, b) in want.iter().zip(&got) {
                    assert_eq!(
                        (a.re.to_bits(), a.im.to_bits()),
                        (b.re.to_bits(), b.im.to_bits()),
                        "worker exchange must be bit-identical"
                    );
                }
                (t_off, t_on, busy)
            });
            let t_off = rows.iter().map(|r| r.0).max().unwrap();
            let t_on = rows.iter().map(|r| r.1).max().unwrap();
            let busy = rows.iter().map(|r| r.2).max().unwrap();
            let note = if p >= 4 && t_on > t_off {
                "worker did not win (timing noise?)"
            } else {
                ""
            };
            println!(
                "{p:>4} {n:>6}^ | {:>11} | {:>11} {:>14} | {note}",
                fmt_us(t_off),
                fmt_us(t_on),
                fmt_us(Duration::from_nanos(busy)),
            );
        }
    }
}

/// c2c vs r2c on the plane-wave sphere: the complex plan against the
/// Hermitian half-spectrum plan, same coefficients, same sphere. The byte
/// columns are exact wire accounting from `ExecTrace` (summed across
/// ranks); the ratio lands on `(nz/2 + 1)/nz` exactly.
fn r2c_section() {
    println!();
    println!("c2c vs r2c sphere exchange (plane-wave forward, window 2), skew {SKEW_US}us/rank");
    println!(
        "{:>4} {:>7} | {:>11} {:>12} | {:>11} {:>12} {:>7} | {}",
        "p", "n", "c2c", "c2c bytes", "r2c", "r2c bytes", "ratio", "note"
    );
    for p in [2usize, 4, 8] {
        for n in [16usize, 32] {
            let nb = 2usize;
            let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
            let off = Arc::new(spec.offsets());
            let rows = run_world(p, move |comm| {
                let me = comm.rank();
                let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
                let backend = RustFftBackend::new();
                let c2c = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
                let r2c = RealPlaneWavePlan::new(Arc::clone(&off), nb, grid).unwrap();
                let zin = phased(c2c.input_len(), 11 + me as u64);
                let xin: Vec<f64> = zin.iter().map(|c| c.re).collect();

                let (mut t_c, mut t_r) = (Duration::ZERO, Duration::ZERO);
                let (mut b_c, mut b_r) = (0u64, 0u64);
                for it in 0..WARMUP + ITERS {
                    barrier(&comm);
                    busy_wait_us(me as u64 * SKEW_US);
                    let t0 = Instant::now();
                    let (out, tr) = c2c.forward(&backend, zin.clone());
                    if it >= WARMUP {
                        t_c += t0.elapsed();
                        b_c += tr.comm_bytes();
                    }
                    c2c.recycle(out);
                }
                for it in 0..WARMUP + ITERS {
                    barrier(&comm);
                    busy_wait_us(me as u64 * SKEW_US);
                    let t0 = Instant::now();
                    let (out, tr) = r2c.forward(&backend, xin.clone());
                    if it >= WARMUP {
                        t_r += t0.elapsed();
                        b_r += tr.comm_bytes();
                    }
                    r2c.recycle(out);
                }
                (t_c / ITERS as u32, t_r / ITERS as u32, b_c / ITERS as u64, b_r / ITERS as u64)
            });
            let t_c = rows.iter().map(|r| r.0).max().unwrap();
            let t_r = rows.iter().map(|r| r.1).max().unwrap();
            let b_c: u64 = rows.iter().map(|r| r.2).sum();
            let b_r: u64 = rows.iter().map(|r| r.3).sum();
            // Exact accounting, not timing: summed across ranks the r2c
            // exchange must carry fewer than 0.6x the c2c bytes.
            assert!(b_r * 10 < b_c * 6, "r2c bytes not halved at p={p}, n={n}: {b_r} vs {b_c}");
            let note = if t_r > t_c { "r2c did not win (timing noise?)" } else { "" };
            println!(
                "{p:>4} {n:>6}^ | {:>11} {:>12} | {:>11} {:>12} {:>7.4} | {note}",
                fmt_us(t_c),
                b_c,
                fmt_us(t_r),
                b_r,
                b_r as f64 / b_c as f64,
            );
        }
    }
}

fn main() {
    println!("pairwise exchange: serial vs overlapped (window = p-1), skew {SKEW_US}us/rank");
    println!(
        "{:>4} {:>7} | {:>11} {:>12} | {:>11} {:>12} | {}",
        "p", "total", "serial", "serial-wait", "overlap", "overlap-wait", "note"
    );
    for p in [2usize, 4, 8] {
        for kb in [64usize, 256] {
            let elems = (kb * 1024 / std::mem::size_of::<Complex>()) / p;
            let rows = run_world(p, move |comm| {
                let me = comm.rank();
                let send: Vec<Complex> =
                    (0..elems * p).map(|i| Complex::new(i as f64, me as f64)).collect();
                let offs: Vec<usize> = (0..=p).map(|j| j * elems).collect();
                let mut recv = vec![ZERO; elems * p];

                let mut bench_discipline = |window: Option<usize>| -> (Duration, ExecTrace) {
                    let mut trace = ExecTrace::default();
                    let mut elapsed = Duration::ZERO;
                    for it in 0..WARMUP + ITERS {
                        barrier(&comm);
                        // Deterministic start skew: rank r enters the
                        // exchange r*SKEW_US later (imbalanced pack).
                        busy_wait_us(me as u64 * SKEW_US);
                        let t0 = Instant::now();
                        let c = match window {
                            None => alltoallv_complex_flat_serial(
                                &comm, &send, &offs, &mut recv, &offs,
                            ),
                            Some(w) => alltoallv_complex_flat_tuned(
                                &comm,
                                &send,
                                &offs,
                                &mut recv,
                                &offs,
                                CommTuning::with_window(w),
                            ),
                        };
                        if it >= WARMUP {
                            elapsed += t0.elapsed();
                            trace.wait_ns += c.wait_ns;
                            trace.overlap_rounds += c.overlap_rounds;
                        }
                    }
                    (elapsed / ITERS as u32, trace)
                };

                let (t_serial, tr_serial) = bench_discipline(None);
                let (t_over, tr_over) = bench_discipline(Some((p - 1).max(1)));
                (t_serial, tr_serial.wait_ns, t_over, tr_over.wait_ns)
            });
            // Slowest rank gates the exchange.
            let t_serial = rows.iter().map(|r| r.0).max().unwrap();
            let w_serial = rows.iter().map(|r| r.1).max().unwrap() / ITERS as u64;
            let t_over = rows.iter().map(|r| r.2).max().unwrap();
            let w_over = rows.iter().map(|r| r.3).max().unwrap() / ITERS as u64;
            let note = if p >= 4 && w_over >= w_serial {
                "overlap did not cut wait (timing noise?)"
            } else {
                ""
            };
            println!(
                "{p:>4} {:>6}K | {:>11} {:>12} | {:>11} {:>12} | {note}",
                kb,
                fmt_us(t_serial),
                fmt_us(Duration::from_nanos(w_serial)),
                fmt_us(t_over),
                fmt_us(Duration::from_nanos(w_over)),
            );
        }
    }
    fused_section();
    worker_section();
    r2c_section();
    println!("a2a_micro bench done");
}
