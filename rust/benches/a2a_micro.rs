use fftb::comm::{alltoallv, run_world};
use std::time::Instant;

fn main() {
    for p in [2usize, 4, 8] {
        for kb in [16usize, 64, 256] {
            let times = run_world(p, move |comm| {
                let block = vec![0u8; kb * 1024 / p];
                // warmup
                for _ in 0..5 {
                    let send: Vec<Vec<u8>> = (0..p).map(|_| block.clone()).collect();
                    alltoallv(&comm, send);
                }
                let t0 = Instant::now();
                let iters = 50;
                for _ in 0..iters {
                    let send: Vec<Vec<u8>> = (0..p).map(|_| block.clone()).collect();
                    alltoallv(&comm, send);
                }
                t0.elapsed() / iters
            });
            println!("p={p} total={kb}KB per-rank: {:?}", times.iter().max().unwrap());
        }
    }
}
