//! Service ablation: coalesced multi-tenant batching vs isolated runs.
//!
//! The service's claim is that several SCF tenants sharing one lane ride
//! shared batched executions — one fused exchange per flush instead of
//! one per stream — at no numerical cost (bit-identity is pinned by
//! `tests/service.rs`; this bench measures the *price* side). Two
//! configurations, identical physics and seeds:
//!
//! * `coalesced` — all tenants on ONE [`ScfServiceDriver`]: every
//!   lockstep iteration runs three coalesced flushes total;
//! * `isolated` — each tenant alone on its own driver, run back to back:
//!   three flushes per iteration *per tenant*.
//!
//! Printed per configuration: wall time, fused-exchange point-to-point
//! message count, and each tenant's p95 submit-to-completion latency.
//!
//! Run: `cargo bench --bench service_ablation`

use std::time::{Duration, Instant};

use fftb::comm::communicator::run_world;
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfServiceDriver};
use fftb::fftb::backend::RustFftBackend;
use fftb::service::ServiceConfig;

const N: usize = 16;
const A: f64 = 10.0;
const ECUT: f64 = 2.5;
const P: usize = 4;
const ITERS: usize = 5;
/// Band counts of the tenants — deliberately unequal so the coalesced
/// batches are ragged across tenants, the realistic case.
const NBS: [usize; 3] = [2, 3, 4];

fn opts(seed_off: usize) -> ScfOptions {
    ScfOptions {
        max_iters: ITERS,
        tol: 0.0,
        coupling: 0.3,
        seed: 42 + seed_off as u64,
        ..Default::default()
    }
}

fn potential() -> GaussianWells {
    GaussianWells::dimer(3.0, 1.3, 0.35)
}

/// Run `tenants` (indexes into [`NBS`]) on one shared driver; returns
/// (wall, fused-exchange messages, per-tenant p95 rows) from rank 0.
fn run_shared(tenants: &'static [usize]) -> (Duration, u64, Vec<String>) {
    let t0 = Instant::now();
    let outs = run_world(P, move |comm| {
        let backend = RustFftBackend::new();
        let lat = Lattice::new(A, N, ECUT);
        let mut driver = ScfServiceDriver::new(&lat, &comm, ServiceConfig::default())
            .expect("the service must assemble");
        for &t in tenants {
            driver
                .add_tenant(
                    &format!("scf-{t}"),
                    lat.clone(),
                    NBS[t],
                    &potential(),
                    &comm,
                    opts(t),
                )
                .expect("tenant registration is infallible here");
        }
        let results = driver.run(&backend).expect("the lockstep loop must run");
        for res in &results {
            let nb = res.eigenvalues.len() as f64;
            assert!(
                (res.density.charge - nb).abs() < 1e-6,
                "charge drift in a service-driven tenant"
            );
        }
        let rows: Vec<String> = driver
            .service()
            .metrics()
            .tenant_metrics()
            .iter()
            .map(|t| {
                format!(
                    "{:<8} p95 {:?}",
                    t.label,
                    t.p95().expect("every tenant completed requests")
                )
            })
            .collect();
        (driver.service().metrics().total_messages(), rows)
    });
    let wall = t0.elapsed();
    let (messages, rows) = outs.into_iter().next().unwrap();
    (wall, messages, rows)
}

fn main() {
    println!(
        "service ablation: {N}^3 grid, ecut={ECUT}, tenants nb={NBS:?}, p={P}, {ITERS} iterations"
    );

    // Coalesced: every tenant on one driver, shared flushes.
    let (co_wall, co_msgs, co_rows) = run_shared(&[0, 1, 2]);

    // Isolated: the same tenants back to back, each alone on its driver.
    static SOLO: [[usize; 1]; 3] = [[0], [1], [2]];
    let mut iso_wall = Duration::ZERO;
    let mut iso_msgs = 0u64;
    let mut iso_rows = Vec::new();
    for solo in &SOLO {
        let (w, m, rows) = run_shared(solo);
        iso_wall += w;
        iso_msgs += m;
        iso_rows.extend(rows);
    }

    println!("{:>10} {:>10} {:>10}", "config", "wall", "messages");
    println!("{:>10} {:>10.1?} {:>10}", "coalesced", co_wall, co_msgs);
    println!("{:>10} {:>10.1?} {:>10}", "isolated", iso_wall, iso_msgs);
    println!();
    println!("per-tenant p95 (coalesced):");
    for r in &co_rows {
        println!("  {r}");
    }
    println!("per-tenant p95 (isolated):");
    for r in &iso_rows {
        println!("  {r}");
    }

    // The whole point: sharing the flushes must cut the exchange count —
    // three fused exchanges per iteration total, not per tenant — and the
    // saved latency must show up on the wall clock.
    assert!(
        co_msgs < iso_msgs,
        "coalesced flushes must send fewer messages than isolated runs \
         ({co_msgs} vs {iso_msgs})"
    );
    assert!(
        co_wall < iso_wall.mul_f64(1.25),
        "the coalesced loop fell behind the isolated runs"
    );
    println!("service_ablation bench done");
}
