//! Bench: Table 1 — the capability matrix, demonstrated live.
//!
//! The paper's Table 1 contrasts FFTB with FFTE/heFFTe/FFTX/FFTU/elemental:
//! FFTB uniquely covers {CtoC} x {cuboid, sphere} x {1D, 2D, 3D grids} x
//! {batched}. This bench runs one real transform per capability cell and
//! prints the matrix with timings — a cell is only ✓ if the transform
//! executes AND round-trips correctly.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fft::complex::max_abs_diff;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::domain::{Domain, DomainList};
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{Fftb, FftbOptions};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::fftb::tensor::DistTensor;
use fftb::util::stats::fmt_duration;

struct Cell {
    label: &'static str,
    ok: bool,
    time: std::time::Duration,
    plan: String,
}

fn run_cell(
    label: &'static str,
    grid_dims: &'static [usize],
    in_layout: &'static str,
    out_layout: &'static str,
    nb: usize,
    sphere: bool,
    opts: FftbOptions,
) -> Cell {
    let n = 16usize;
    let p: usize = grid_dims.iter().product();
    let outs = run_world(p, move |comm| {
        let g = ProcGrid::new(grid_dims, comm).unwrap();
        let mut parts = Vec::new();
        if nb > 1 {
            parts.push(Domain::new(vec![0], vec![nb as i64 - 1]).unwrap());
        }
        let cube = Domain::new(vec![0, 0, 0], vec![n as i64 - 1; 3]).unwrap();
        let in_cube = if sphere {
            let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
            Domain::with_offsets(vec![0, 0, 0], vec![n as i64 - 1; 3], Arc::new(spec.offsets()))
                .unwrap()
        } else {
            cube.clone()
        };
        let mut in_parts = parts.clone();
        in_parts.push(in_cube);
        let mut out_parts = parts;
        out_parts.push(cube);

        // Layout-by-plan: a 3D grid is folded to (d0*d1, d2) by the
        // planner, and the tensors must be declared against that folded
        // grid so their local sizing matches the plan's layouts.
        let tg = if grid_dims.len() == 3 { g.fold().unwrap() } else { g.clone() };
        let ti = DistTensor::zeros(DomainList::new(in_parts).unwrap(), in_layout, tg.clone())
            .unwrap();
        let to = DistTensor::zeros(DomainList::new(out_parts).unwrap(), out_layout, tg)
            .unwrap();
        let fx = match Fftb::plan_opt([n, n, n], &to, "X Y Z", &ti, "x y z", g.clone(), opts) {
            Ok(fx) => fx,
            Err(e) => return (false, std::time::Duration::ZERO, format!("{e}")),
        };
        let backend = RustFftBackend::new();
        let input = phased(fx.input_len(), 7);
        let t0 = std::time::Instant::now();
        let (spec, _) = fx.execute(&backend, input.clone(), Direction::Forward);
        let (back, _) = fx.execute(&backend, spec, Direction::Inverse);
        let dt = t0.elapsed();
        let ok = max_abs_diff(&back, &input) < 1e-9;
        (ok, dt, fx.kind.name().to_string())
    });
    let ok = outs.iter().all(|o| o.0);
    let time = outs.iter().map(|o| o.1).max().unwrap();
    Cell { label, ok, time, plan: outs[0].2.clone() }
}

fn main() {
    println!("== Table 1: FFTB capability matrix (live, 16^3, fwd+inv round trip) ==");
    let cells = vec![
        run_cell("CtoC cuboid, 1D grid", &[4], "x{0} y z", "X Y Z{0}", 1, false,
            FftbOptions::default()),
        run_cell("CtoC cuboid, 2D grid", &[2, 2], "x y{0} z{1}", "X{0} Y{1} Z", 1, false,
            FftbOptions::default()),
        run_cell("CtoC cuboid, 3D grid (folded)", &[2, 2, 2], "x y{0} z{1}", "X{0} Y{1} Z", 1,
            false, FftbOptions::default()),
        run_cell("CtoC cuboid, batched (nb=4)", &[4], "b x{0} y z", "B X Y Z{0}", 4, false,
            FftbOptions::default()),
        run_cell("CtoC cuboid, non-batched loop", &[4], "b x{0} y z", "B X Y Z{0}", 4, false,
            FftbOptions { force_non_batched: true, ..Default::default() }),
        run_cell("CtoC sphere (plane-wave), batched", &[4], "b x{0} y z", "B X Y Z{0}", 4, true,
            FftbOptions::default()),
        run_cell("CtoC sphere, padded baseline", &[4], "b x{0} y z", "B X Y Z{0}", 4, true,
            FftbOptions { pad_sphere_to_cube: true, ..Default::default() }),
    ];

    println!("{:<38} {:>6} {:>12}  plan", "capability", "status", "round-trip");
    let mut all_ok = true;
    for c in &cells {
        println!(
            "{:<38} {:>6} {:>12}  {}",
            c.label,
            if c.ok { "OK" } else { "FAIL" },
            fmt_duration(c.time),
            c.plan
        );
        all_ok &= c.ok;
    }
    assert!(all_ok, "every Table 1 capability cell must pass");
    println!("table1_capabilities bench done — all {} cells pass", cells.len());
}
