//! Bench: Fig. 1 ablation — slab-pencil (1 alltoall over p ranks) vs
//! pencil-pencil (2 alltoalls over sqrt(p)-rank sub-communicators) at equal
//! total rank counts.
//!
//! The trade: the pencil plan moves more total bytes in two rounds but each
//! round spans fewer ranks (smaller latency factor at scale); the slab plan
//! is one big exchange. On the latency-free in-process testbed the slab
//! plan usually wins; the modeled section shows where the 2D grid pays off.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{PencilPlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{grid_2d, project, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn main() {
    println!("== live: slab (1D grid) vs pencil (2D grid), cube 32^3 nb=4 ==");
    println!(
        "{:>4} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "p", "grid", "bytes-slab", "bytes-pencil", "t-slab", "t-pencil"
    );
    let n = 32usize;
    let nb = 4usize;
    for p in [4usize, 8, 16] {
        let (p0, p1) = grid_2d(p);
        let rows = run_world(p, move |comm| {
            let g1 = ProcGrid::new(&[p], comm.clone()).unwrap();
            let g2 = ProcGrid::new(&[p0, p1], comm).unwrap();
            let backend = RustFftBackend::new();
            let slab = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&g1)).unwrap();
            let pencil = PencilPlan::new([n, n, n], nb, Arc::clone(&g2)).unwrap();
            let in1 = phased(slab.input_len(), 1);
            let in2 = phased(pencil.input_len(), 2);

            let mut b1 = 0u64;
            let t1 = bench(2, 5, || {
                let (_, tr) = slab.forward(&backend, in1.clone());
                b1 = tr.comm_bytes();
            });
            let mut b2 = 0u64;
            let t2 = bench(2, 5, || {
                let (_, tr) = pencil.forward(&backend, in2.clone());
                b2 = tr.comm_bytes();
            });
            (b1, b2, t1.mean(), t2.mean())
        });
        println!(
            "{p:>4} {:>8} {:>12} {:>12} {:>10} {:>10}",
            format!("{p0}x{p1}"),
            rows[0].0,
            rows[0].1,
            fmt_duration(rows.iter().map(|r| r.2).max().unwrap()),
            fmt_duration(rows.iter().map(|r| r.3).max().unwrap()),
        );
    }

    println!();
    println!("== modeled crossover at paper scale (256^3, nb=256) ==");
    println!("{:>5} {:>12} {:>12} {:>10}", "p", "slab-1D", "pencil-2D", "winner");
    let nn = 256usize;
    let spec = SphereSpec::new([nn, nn, nn], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [nn, nn, nn], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();
    for p in [16usize, 64, 256, 1024] {
        let t1 = project(Variant::Slab1dBatched, &w, p, &m);
        let t2 = project(Variant::Pencil2dBatched, &w, p, &m);
        println!(
            "{p:>5} {:>10.2}ms {:>10.2}ms {:>10}",
            t1 * 1e3,
            t2 * 1e3,
            if t1 <= t2 { "slab" } else { "pencil" }
        );
    }
    println!("decomposition_ablation bench done");
}
