//! Bench: node-local line-FFT throughput — the L1/L3 hot path.
//!
//! Measures the rust Stockham substrate (the live executor backend) across
//! line lengths, and the PJRT/Pallas artifact path when `artifacts/` exists.
//! The rust numbers calibrate the performance model's compute rate; the
//! comparison is also the §Perf baseline in EXPERIMENTS.md.
//!
//! Reported GFLOP/s uses the 5 n log2 n convention per complex line.

use std::sync::Arc;

use fftb::fft::batch::fft_flops;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::{LocalFftBackend, RustFftBackend};
use fftb::fftb::plan::testutil::phased;
use fftb::runtime::{PjrtFftBackend, PjrtRuntime};
use fftb::util::stats::bench;

fn throughput(backend: &dyn LocalFftBackend, n: usize, nlines: usize) -> (f64, f64) {
    let data0 = phased(n * nlines, n as u64);
    let mut data = data0.clone();
    let s = bench(3, 10, || {
        data.copy_from_slice(&data0);
        backend.fft_batch(&mut data, n, Direction::Forward);
    });
    let secs = s.mean().as_secs_f64();
    let flops = nlines as f64 * fft_flops(n);
    (flops / secs / 1e9, secs)
}

fn main() {
    println!("== local batched line-FFT throughput (forward, 4096 lines) ==");
    let rust = RustFftBackend::new();
    let pjrt = PjrtRuntime::open("artifacts")
        .ok()
        .map(|rt| PjrtFftBackend::new(Arc::new(rt)));

    println!(
        "{:>6} {:>16} {:>16} {:>10}",
        "n", "rust GF/s", "pjrt GF/s", "ratio"
    );
    for n in [16usize, 32, 64, 128, 256] {
        let nlines = 4096;
        let (gr, _) = throughput(&rust, n, nlines);
        match &pjrt {
            Some(pb) => {
                let (gp, _) = throughput(pb, n, nlines);
                println!("{n:>6} {gr:>16.2} {gp:>16.2} {:>10.2}", gr / gp);
            }
            None => println!("{n:>6} {gr:>16.2} {:>16} {:>10}", "n/a", "-"),
        }
    }
    // Calibration line for the model (local_cpu machine description).
    let (g64, _) = throughput(&rust, 64, 4096);
    let (g256, _) = throughput(&rust, 256, 4096);
    println!();
    println!(
        "model calibration: rust backend sustains {:.2} GF/s (n=64) / {:.2} GF/s (n=256)",
        g64, g256
    );

    pack_ablation(&rust);
    println!("local_fft bench done");
}

/// §Perf L3 iteration 4 evidence: strided-gather pack (the pre-optimization
/// path, still used for scattered line subsets) vs the blocked-transpose
/// panel pack now used by `backend_fft_dim` — same transform, same data.
fn pack_ablation(rust: &RustFftBackend) {
    use fftb::fftb::backend::{backend_fft_dim, fft_strided_lines};
    println!();
    println!("== pack ablation: strided gather vs blocked-transpose panel ==");
    println!("{:>22} {:>12} {:>12} {:>8}", "shape(dim=1)", "gather", "panel", "speedup");
    for (nb, n, rest) in [(8usize, 64usize, 64usize), (16, 128, 32), (4, 256, 64)] {
        let shape = [nb, n, rest, 1];
        let data0 = phased(nb * n * rest, 7);

        // Old path: explicit start list + strided gather/scatter.
        let mut d1 = data0.clone();
        let mut starts = Vec::new();
        for o in 0..rest {
            for i in 0..nb {
                starts.push(o * nb * n + i);
            }
        }
        let t_gather = bench(2, 8, || {
            d1.copy_from_slice(&data0);
            fft_strided_lines(rust, &mut d1, n, nb, &starts, Direction::Forward);
        });

        // New path: backend_fft_dim (blocked transpose).
        let mut d2 = data0.clone();
        let t_panel = bench(2, 8, || {
            d2.copy_from_slice(&data0);
            backend_fft_dim(rust, &mut d2, &shape, 1, Direction::Forward);
        });
        // Same numerics.
        let err = fftb::fft::complex::max_abs_diff(&d1, &d2);
        assert!(err < 1e-12, "paths disagree: {err}");
        let (tg, tp) = (t_gather.min().as_secs_f64(), t_panel.min().as_secs_f64());
        println!(
            "{:>22} {:>12} {:>12} {:>7.2}x",
            format!("[{nb},{n},{rest}]"),
            fftb::util::stats::fmt_duration(t_gather.min()),
            fftb::util::stats::fmt_duration(t_panel.min()),
            tg / tp
        );
    }
}
