//! SCF ablation: the full density loop, auto-tuned vs hand-picked plans.
//!
//! The paper's red-line workload is the SCF iteration — batched
//! sphere-forward + inverse per Hamiltonian application plus one density
//! forward — repeated every iteration. This bench runs the *whole* loop
//! (fixed iteration budget, identical physics and seeds) under:
//!
//! * `auto (model)` — `ScfRunner::new`, tuner decides from the cost model;
//! * `auto (scf-probe)` — tuner additionally executes its shortlist once
//!   in the SCF-shaped alternating fwd/inv cadence and keeps the measured
//!   winner;
//! * `pinned plane-wave` — the hand-picked batched staged-padding plan;
//! * `pinned plane-wave-loop` — the per-band exchange cadence;
//! * `pinned pad-to-cube` — the Fig. 2 baseline.
//!
//! Printed per configuration: wall time of the run, per-iteration mean,
//! plan-cache hit rate and total workspace growth over the loop's
//! transforms.
//!
//! Run: `cargo bench --bench scf_ablation`

use std::sync::Arc;
use std::time::{Duration, Instant};

use fftb::comm::communicator::run_world;
use fftb::coordinator::MetricsSink;
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::{
    Fftb, PaddedSpherePlan, PlanKind, PlaneWaveLoop, PlaneWavePlan,
};

const N: usize = 16;
const A: f64 = 10.0;
const ECUT: f64 = 2.5;
const NB: usize = 6;
const P: usize = 4;
const ITERS: usize = 6;

fn opts() -> ScfOptions {
    ScfOptions { max_iters: ITERS, tol: 0.0, coupling: 0.3, ..Default::default() }
}

fn lattice() -> Lattice {
    Lattice::new(A, N, ECUT)
}

fn potential() -> GaussianWells {
    GaussianWells::dimer(3.0, 1.3, 0.35)
}

/// Run one configuration; returns (kind label, wall, cache rate, alloc B).
fn run_config(mk: &'static str) -> (String, Duration, f64, u64) {
    let t0 = Instant::now();
    let outs = run_world(P, move |comm| {
        let backend = RustFftBackend::new();
        let lat = lattice();
        let off = Arc::clone(&lat.offsets);
        let mut runner = match mk {
            "auto-model" => {
                ScfRunner::new(lat, NB, &potential(), &comm, &backend, opts()).unwrap()
            }
            "auto-scf-probe" => {
                let o = ScfOptions { empirical_top_k: 3, ..opts() };
                ScfRunner::new(lat, NB, &potential(), &comm, &backend, o).unwrap()
            }
            pinned => {
                let grid = ProcGrid::new(&[P], comm.clone()).unwrap();
                let kind = match pinned {
                    "plane-wave" => {
                        PlanKind::PlaneWave(PlaneWavePlan::new(off, NB, grid).unwrap())
                    }
                    "plane-wave-loop" => {
                        PlanKind::PlaneWaveLoop(PlaneWaveLoop::new(off, NB, grid).unwrap())
                    }
                    "pad-to-cube" => {
                        PlanKind::PaddedSphere(PaddedSpherePlan::new(off, NB, grid).unwrap())
                    }
                    other => panic!("unknown config {other}"),
                };
                let plan = Arc::new(Fftb { kind, sizes: [N, N, N], nb: NB });
                ScfRunner::with_plan(lat, NB, &potential(), &comm, plan, opts()).unwrap()
            }
        };
        let res = runner.run(&backend);
        let mut sink = MetricsSink::new(mk);
        for t in runner.drain_traces() {
            sink.record(t);
        }
        (res, sink.cache_hit_rate(), sink.total_alloc_bytes())
    });
    let wall = t0.elapsed();
    let (res, _, _) = &outs[0];
    // Sanity: identical physics in every configuration.
    for (r, _, _) in &outs {
        assert!((r.density.charge - NB as f64).abs() < 1e-6, "charge drift under {mk}");
    }
    let hit = outs.iter().map(|o| o.1).fold(1.0f64, f64::min);
    let alloc = outs.iter().map(|o| o.2).max().unwrap();
    (res.plan_kind.clone(), wall, hit, alloc)
}

fn main() {
    println!(
        "SCF ablation: {N}^3 grid, ecut={ECUT}, nb={NB}, p={P}, {ITERS} iterations"
    );
    println!(
        "{:>16} {:>44} {:>10} {:>10} {:>8} {:>10}",
        "config", "executed plan", "wall", "per-iter", "cache", "alloc"
    );
    let configs =
        ["auto-model", "auto-scf-probe", "plane-wave", "plane-wave-loop", "pad-to-cube"];
    let mut rows = Vec::new();
    for mk in configs {
        let (kind, wall, hit, alloc) = run_config(mk);
        println!(
            "{:>16} {:>44} {:>10.1?} {:>10.1?} {:>8.2} {:>8} B",
            mk,
            kind,
            wall,
            wall / ITERS as u32,
            hit,
            alloc
        );
        rows.push((mk, wall));
    }
    // The auto-tuned loop must not lose badly to the best hand-picked plan
    // (it should *be* the best plan, modulo tuning overhead amortized over
    // only a handful of iterations here), and the pad-to-cube baseline
    // must not win.
    let wall_of = |name: &str| rows.iter().find(|r| r.0 == name).unwrap().1;
    let best_pinned = wall_of("plane-wave").min(wall_of("plane-wave-loop"));
    assert!(
        wall_of("auto-model") < best_pinned.mul_f64(1.5),
        "auto-tuned run fell far behind the best hand-picked plan"
    );
    assert!(
        best_pinned < wall_of("pad-to-cube").mul_f64(1.05),
        "staged padding must not lose to the pad-to-cube baseline"
    );
    println!("scf_ablation bench done");
}
