//! Bench: §2.2 padding ablation — staged padding (Fig. 3) vs pad-to-cube
//! (Fig. 2) on d = n/2 spheres.
//!
//! The paper: "the amount of data is increased by almost 16 times" when the
//! sphere is padded up front. This bench measures, per size: the data
//! blow-up, the bytes each approach puts on the wire, and wall time — and
//! asserts the staged plan wins on all three.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{PaddedSpherePlan, PlaneWavePlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::util::stats::{bench, fmt_duration};

fn main() {
    println!("== padding ablation: staged (Fig. 3) vs padded-cube (Fig. 2), d = n/2 ==");
    println!(
        "{:>5} {:>9} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "n", "blow-up", "staged B", "padded B", "B ratio", "staged t", "padded t", "t ratio"
    );

    let p = 4usize;
    let nb = 4usize;
    for n in [16usize, 32, 48] {
        let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
        let off = Arc::new(spec.offsets());
        let blowup = (n * n * n) as f64 / off.total() as f64;

        let off2 = Arc::clone(&off);
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let staged = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let padded = PaddedSpherePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let input = phased(staged.input_len(), 9);

            let mut staged_bytes = 0u64;
            let t_staged = bench(2, 5, || {
                let (_, tr) = staged.forward(&backend, input.clone());
                staged_bytes = tr.comm_bytes();
            });
            let mut padded_bytes = 0u64;
            let t_padded = bench(2, 5, || {
                let (_, tr) = padded.forward(&backend, input.clone());
                padded_bytes = tr.comm_bytes();
            });
            (staged_bytes, padded_bytes, t_staged.mean(), t_padded.mean())
        });

        let sb = rows.iter().map(|r| r.0).max().unwrap();
        let pb = rows.iter().map(|r| r.1).max().unwrap();
        let st = rows.iter().map(|r| r.2).max().unwrap();
        let pt = rows.iter().map(|r| r.3).max().unwrap();
        println!(
            "{n:>5} {blowup:>8.1}x {sb:>12} {pb:>12} {:>7.1}x {:>12} {:>12} {:>7.2}x",
            pb as f64 / sb as f64,
            fmt_duration(st),
            fmt_duration(pt),
            pt.as_secs_f64() / st.as_secs_f64()
        );
        // Paper claims: ~16x data blow-up; staged strictly cheaper.
        assert!(blowup > 10.0 && blowup < 25.0, "blow-up {blowup} out of range");
        assert!(sb * 3 < pb, "staged must move <1/3 the bytes");
        assert!(st < pt, "staged must be faster end to end");
    }
    println!("padding_ablation bench done");
}
