//! Bench: tuner ablation — how good is the model-picked plan against the
//! live-measured candidate set?
//!
//! For a dense cube and a sphere workload, every feasible decomposition is
//! built (at its model-best window), executed, and timed; the table prints
//! model-predicted seconds next to measured wall time. The assertions pin
//! the tuner's value proposition: the model pick lands in the top tier of
//! the measured set (top-2 for the cube, outright winner for the sphere,
//! where staged padding vs pad-to-cube is a ~3x gap), and the spread
//! between the best and worst candidate is what auto-tuning saves a user
//! who would otherwise hand-pick blind.

use std::sync::Arc;
use std::time::Duration;

use fftb::comm::run_world;
use fftb::fft::complex::ZERO;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::sphere::{OffsetArray, SphereKind, SphereSpec};
use fftb::model::Machine;
use fftb::tuner::search::{self, TuneRequest, WorkloadProfile};

/// Execute every shortlisted candidate (one per decomposition, at its
/// model-best window — `search::shortlist`, the same list the tuner's
/// empirical mode measures) live; returns (label, window, predicted,
/// measured critical-path wall time) in model order.
fn measure(
    shape: [usize; 3],
    nb: usize,
    p: usize,
    sphere: Option<Arc<OffsetArray>>,
) -> Vec<(String, usize, f64, Duration)> {
    let req = TuneRequest { shape, nb, p, sphere, profile: WorkloadProfile::Forward, real: false };
    let cands = search::shortlist(&req, &Machine::local_cpu(), usize::MAX);
    assert!(!cands.is_empty(), "no feasible candidate for {shape:?} on p={p}");
    let req2 = req.clone();
    let cands2 = cands.clone();
    let times = run_world(p, move |comm| {
        let backend = RustFftBackend::new();
        cands2
            .iter()
            .map(|cand| {
                let plan = search::build(cand, &req2, &comm).expect("candidate must build");
                // Warm the workspaces, then keep the fastest of 5.
                let mut best = Duration::MAX;
                for _ in 0..6 {
                    let input = vec![ZERO; plan.input_len()];
                    let t0 = std::time::Instant::now();
                    let (out, _) = plan.execute(&backend, input, Direction::Forward);
                    let dt = t0.elapsed();
                    plan.recycle(out);
                    if dt < best {
                        best = dt;
                    }
                }
                best
            })
            .collect::<Vec<_>>()
    });
    cands
        .iter()
        .enumerate()
        .map(|(i, c)| {
            // Critical path: slowest rank gates the exchange.
            let wall = times.iter().map(|per_rank| per_rank[i]).max().unwrap();
            (c.kind.label(), c.window, c.predicted, wall)
        })
        .collect()
}

fn print_table(title: &str, rows: &[(String, usize, f64, Duration)]) {
    println!("== {title} ==");
    println!("{:>20} {:>7} {:>12} {:>12}", "candidate", "window", "predicted", "measured");
    for (label, window, predicted, wall) in rows {
        println!(
            "{label:>20} {window:>7} {:>10.3}ms {:>10.3}ms",
            predicted * 1e3,
            wall.as_secs_f64() * 1e3
        );
    }
}

fn cube() {
    let (shape, nb, p) = ([32usize, 32, 32], 4usize, 4usize);
    let rows = measure(shape, nb, p, None);
    print_table("cube 32^3, nb=4, p=4 (model order)", &rows);

    // Model pick = first row. Rank it inside the measured set.
    let model_pick = rows[0].3;
    let mut measured: Vec<Duration> = rows.iter().map(|r| r.3).collect();
    measured.sort();
    let top2 = measured[1.min(measured.len() - 1)];
    assert!(
        model_pick <= top2.mul_f64(1.25),
        "model pick ({model_pick:?}) must sit in the measured top-2 (cutoff {top2:?})"
    );
    let spread = measured.last().unwrap().as_secs_f64() / measured[0].as_secs_f64();
    println!("best/worst measured spread: {spread:.1}x");
    assert!(spread > 1.0, "candidates must actually differ");
}

fn sphere() {
    let n = 32usize;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (4usize, 4usize);
    let rows = measure([n, n, n], nb, p, Some(off));
    println!();
    print_table("sphere d=n/2 in 32^3, nb=4, p=4 (model order)", &rows);
    assert_eq!(rows[0].0, "plane-wave", "model must pick staged padding");
    let winner = rows.iter().min_by_key(|r| r.3).unwrap();
    // The two staged-padding cadences (one fused batched exchange vs the
    // per-band loop) run nearly identical work in-process, so either may
    // take the measured crown on a given run — but pad-to-cube must not.
    assert!(
        winner.0.starts_with("plane-wave"),
        "staged padding must also win the measurement (got {winner:?})"
    );
}

fn main() {
    cube();
    sphere();
    println!("tuner_ablation bench done");
}
