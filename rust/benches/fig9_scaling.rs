//! Bench: Figure 9 — strong scaling of five distributed FFT variants.
//!
//! Live section: the real planner + real alltoalls on the in-process
//! testbed at reduced size (cube 32^3, batch 8, sphere d=16), p = 1..8.
//! Modeled section: exact planner counts priced on the Perlmutter machine
//! description at paper scale (cube 256^3, batch 256, sphere d=128),
//! p = 4..1024.
//!
//! Expected shape (the paper's two findings, §4.2):
//!   1. batched >= non-batched everywhere, gap widening with p;
//!   2. the plane-wave transform beats the batched cube transform and
//!      scales near-linearly.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{NonBatchedLoop, PencilPlan, PlaneWavePlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{fig9_row, grid_2d, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn live_section() {
    let n = 32usize;
    let nb = 8usize;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());

    println!("== live strong scaling: cube {n}^3, nb={nb}, sphere d={} ==", n / 2);
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14}",
        "p", "slab-batched", "slab-loop", "pencil-batched", "planewave"
    );

    let mut prev_pw = f64::INFINITY;
    for p in [1usize, 2, 4, 8] {
        let off2 = Arc::clone(&off);
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let backend = RustFftBackend::new();
            let slab = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let pw = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let input = phased(slab.input_len(), 3);
            let pw_in = phased(pw.input_len(), 5);

            // Paper methodology: warmup + timed hot phase, mean reported.
            let t_slab = bench(3, 10, || {
                let _ = slab.forward(&backend, input.clone());
            });
            let t_loop = bench(1, 3, || {
                let _ = looped.forward(&backend, input.clone());
            });
            let t_pw = bench(3, 10, || {
                let _ = pw.forward(&backend, pw_in.clone());
            });
            let (p0, p1) = grid_2d(p);
            let t_pencil = if p > 1 {
                let g2 = ProcGrid::new(&[p0, p1], comm).unwrap();
                let pencil = PencilPlan::new([n, n, n], nb, Arc::clone(&g2)).unwrap();
                let pin = phased(pencil.input_len(), 6);
                bench(3, 10, || {
                    let _ = pencil.forward(&backend, pin.clone());
                })
                .mean()
                .as_secs_f64()
            } else {
                t_slab.mean().as_secs_f64()
            };
            (
                t_slab.mean().as_secs_f64(),
                t_loop.mean().as_secs_f64(),
                t_pencil,
                t_pw.mean().as_secs_f64(),
            )
        });
        let worst =
            |f: fn(&(f64, f64, f64, f64)) -> f64| rows.iter().map(f).fold(0.0, f64::max);
        let (s, l, pc, pw) = (worst(|r| r.0), worst(|r| r.1), worst(|r| r.2), worst(|r| r.3));
        println!(
            "{p:>4} {:>14} {:>14} {:>14} {:>14}",
            fmt_duration(std::time::Duration::from_secs_f64(s)),
            fmt_duration(std::time::Duration::from_secs_f64(l)),
            fmt_duration(std::time::Duration::from_secs_f64(pc)),
            fmt_duration(std::time::Duration::from_secs_f64(pw)),
        );
        // Shape checks (soft, printed not asserted for timing noise).
        if s > l {
            println!("     note: batched slower than loop at p={p} (timing noise?)");
        }
        if pw > s {
            println!("     note: planewave slower than slab at p={p}");
        }
        prev_pw = prev_pw.min(pw);
    }
}

fn modeled_section() {
    let n = 256usize;
    let spec = SphereSpec::new([n, n, n], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [n, n, n], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();

    println!();
    println!("== modeled at paper scale: cube 256^3, nb=256, sphere d=128 ({}) ==", m.name);
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "p",
        "slab-b",
        "slab-nb",
        "pencil-b",
        "pencil-nb",
        "planewave"
    );
    let mut p = 4;
    while p <= 1024 {
        let row = fig9_row(&w, p, &m);
        println!(
            "{p:>5} {:>11.2}ms {:>11.2}ms {:>11.2}ms {:>11.2}ms {:>11.2}ms",
            row[0] * 1e3,
            row[1] * 1e3,
            row[2] * 1e3,
            row[3] * 1e3,
            row[4] * 1e3
        );
        // The paper's two hard claims:
        assert!(row[0] < row[1], "batched must beat non-batched at p={p}");
        assert!(row[4] < row[0], "planewave must beat batched cube at p={p}");
        p *= 2;
    }
    // Near-linear planewave scaling 4 -> 1024 (paper: "scales almost
    // linear to 1024 GPUs").
    let t4 = fftb::model::project(Variant::PlaneWave, &w, 4, &m);
    let t1024 = fftb::model::project(Variant::PlaneWave, &w, 1024, &m);
    let speedup = t4 / t1024;
    println!("planewave speedup 4->1024: {speedup:.0}x (linear would be 256x)");
    assert!(speedup > 64.0, "planewave should scale well, got {speedup}");
}

fn main() {
    live_section();
    modeled_section();
    println!("fig9_scaling bench done");
}
