//! Bench: Figure 9 — strong scaling of five distributed FFT variants.
//!
//! Live section: the real planner + real alltoalls on the in-process
//! testbed at reduced size (cube 32^3, batch 8, sphere d=16), p = 1..8.
//! Modeled section: exact planner counts priced on the Perlmutter machine
//! description at paper scale (cube 256^3, batch 256, sphere d=128),
//! p = 4..1024.
//!
//! Expected shape (the paper's two findings, §4.2):
//!   1. batched >= non-batched everywhere, gap widening with p;
//!   2. the plane-wave transform beats the batched cube transform and
//!      scales near-linearly.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::comm::CommTuning;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{
    NonBatchedLoop, PencilPlan, PlaneWavePlan, RealPlaneWavePlan, SlabPencilPlan,
};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::model::{fig9_row, grid_2d, price_stages, Machine, Variant, Workload};
use fftb::util::stats::{bench, fmt_duration};

fn live_section() {
    let n = 32usize;
    let nb = 8usize;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());

    println!("== live strong scaling: cube {n}^3, nb={nb}, sphere d={} ==", n / 2);
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14}",
        "p", "slab-batched", "slab-loop", "pencil-batched", "planewave"
    );

    let mut prev_pw = f64::INFINITY;
    for p in [1usize, 2, 4, 8] {
        let off2 = Arc::clone(&off);
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let backend = RustFftBackend::new();
            let slab = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let looped = NonBatchedLoop::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
            let pw = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let input = phased(slab.input_len(), 3);
            let pw_in = phased(pw.input_len(), 5);

            // Paper methodology: warmup + timed hot phase, mean reported.
            let t_slab = bench(3, 10, || {
                let _ = slab.forward(&backend, input.clone());
            });
            let t_loop = bench(1, 3, || {
                let _ = looped.forward(&backend, input.clone());
            });
            let t_pw = bench(3, 10, || {
                let _ = pw.forward(&backend, pw_in.clone());
            });
            let (p0, p1) = grid_2d(p);
            let t_pencil = if p > 1 {
                let g2 = ProcGrid::new(&[p0, p1], comm).unwrap();
                let pencil = PencilPlan::new([n, n, n], nb, Arc::clone(&g2)).unwrap();
                let pin = phased(pencil.input_len(), 6);
                bench(3, 10, || {
                    let _ = pencil.forward(&backend, pin.clone());
                })
                .mean()
                .as_secs_f64()
            } else {
                t_slab.mean().as_secs_f64()
            };
            (
                t_slab.mean().as_secs_f64(),
                t_loop.mean().as_secs_f64(),
                t_pencil,
                t_pw.mean().as_secs_f64(),
            )
        });
        let worst =
            |f: fn(&(f64, f64, f64, f64)) -> f64| rows.iter().map(f).fold(0.0, f64::max);
        let (s, l, pc, pw) = (worst(|r| r.0), worst(|r| r.1), worst(|r| r.2), worst(|r| r.3));
        println!(
            "{p:>4} {:>14} {:>14} {:>14} {:>14}",
            fmt_duration(std::time::Duration::from_secs_f64(s)),
            fmt_duration(std::time::Duration::from_secs_f64(l)),
            fmt_duration(std::time::Duration::from_secs_f64(pc)),
            fmt_duration(std::time::Duration::from_secs_f64(pw)),
        );
        // Shape checks (soft, printed not asserted for timing noise).
        if s > l {
            println!("     note: batched slower than loop at p={p} (timing noise?)");
        }
        if pw > s {
            println!("     note: planewave slower than slab at p={p}");
        }
        prev_pw = prev_pw.min(pw);
    }
}

/// Serial-vs-overlapped comparison on the hottest plan: the same batched
/// slab-pencil forward with exchange window 1 (serial ordering) and
/// window 4 (overlapped pipeline). `wait` is the slowest rank's
/// `ExecTrace::wait_ns` per execution — the overlapped column should show
/// less time-in-wait at p >= 4.
fn overlap_section() {
    let n = 32usize;
    let nb = 8usize;
    println!();
    println!("== exchange overlap ablation: slab-pencil cube {n}^3, nb={nb} ==");
    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14}",
        "p", "w=1 (serial)", "w=1 wait", "w=4 (overlap)", "w=4 wait"
    );
    for p in [2usize, 4, 8] {
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let input = phased(
                SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap().input_len(),
                7,
            );
            let run_window = |w: usize| {
                let mut plan = SlabPencilPlan::new([n, n, n], nb, Arc::clone(&grid)).unwrap();
                plan.set_tuning(CommTuning::with_window(w));
                // Warm the workspaces, then measure.
                let _ = plan.forward(&backend, input.clone());
                let iters = 10usize;
                let mut wait_ns = 0u64;
                let t0 = std::time::Instant::now();
                for _ in 0..iters {
                    let (_, tr) = plan.forward(&backend, input.clone());
                    wait_ns += tr.wait_ns;
                }
                (t0.elapsed() / iters as u32, wait_ns / iters as u64)
            };
            let (t1, w1) = run_window(1);
            let (t4, w4) = run_window(4);
            (t1, w1, t4, w4)
        });
        let t1 = rows.iter().map(|r| r.0).max().unwrap();
        let w1 = rows.iter().map(|r| r.1).max().unwrap();
        let t4 = rows.iter().map(|r| r.2).max().unwrap();
        let w4 = rows.iter().map(|r| r.3).max().unwrap();
        println!(
            "{p:>4} {:>14} {:>14} {:>14} {:>14}",
            fmt_duration(t1),
            fmt_duration(std::time::Duration::from_nanos(w1)),
            fmt_duration(t4),
            fmt_duration(std::time::Duration::from_nanos(w4)),
        );
        if p >= 4 && w4 > w1 {
            println!("     note: overlap did not cut wait at p={p} (timing noise?)");
        }
    }
}

/// r2c-vs-c2c ablation on the plane-wave sphere: the same coefficients
/// forward through the complex plan and the Hermitian half-spectrum plan.
/// The r2c exchange carries only the `nz/2 + 1` unique z bins, so its
/// summed wire bytes come in at `(nz/2 + 1)/nz` of c2c (17/32 here) —
/// the bytes column is exact accounting, the time columns are live means.
fn r2c_section() {
    let n = 32usize;
    let nb = 8usize;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());

    println!();
    println!("== r2c ablation: planewave sphere d={}, cube {n}^3, nb={nb} ==", n / 2);
    println!(
        "{:>4} {:>14} {:>14} {:>12} {:>12} {:>7}",
        "p", "c2c fwd", "r2c fwd", "c2c bytes", "r2c bytes", "ratio"
    );
    for p in [1usize, 2, 4, 8] {
        let off2 = Arc::clone(&off);
        let rows = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let c2c = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let r2c = RealPlaneWavePlan::new(Arc::clone(&off2), nb, grid).unwrap();
            let zin = phased(c2c.input_len(), 9);
            let xin: Vec<f64> = zin.iter().map(|c| c.re).collect();
            let (_, ct) = c2c.forward(&backend, zin.clone());
            let (_, rt) = r2c.forward(&backend, xin.clone());
            let t_c = bench(3, 10, || {
                let _ = c2c.forward(&backend, zin.clone());
            });
            let t_r = bench(3, 10, || {
                let _ = r2c.forward(&backend, xin.clone());
            });
            (
                t_c.mean().as_secs_f64(),
                t_r.mean().as_secs_f64(),
                ct.comm_bytes(),
                rt.comm_bytes(),
            )
        });
        let tc = rows.iter().map(|r| r.0).fold(0.0, f64::max);
        let tr = rows.iter().map(|r| r.1).fold(0.0, f64::max);
        let cb: u64 = rows.iter().map(|r| r.2).sum();
        let rb: u64 = rows.iter().map(|r| r.3).sum();
        let ratio = if cb > 0 { rb as f64 / cb as f64 } else { 1.0 };
        println!(
            "{p:>4} {:>14} {:>14} {:>12} {:>12} {ratio:>7.4}",
            fmt_duration(std::time::Duration::from_secs_f64(tc)),
            fmt_duration(std::time::Duration::from_secs_f64(tr)),
            cb,
            rb,
        );
        if p > 1 {
            // Exact accounting, not timing: the half-spectrum exchange must
            // put fewer than 0.6x the c2c bytes on the wire.
            assert!(rb * 10 < cb * 6, "r2c bytes not halved at p={p}: {rb} vs {cb}");
        }
        if tr > tc {
            println!("     note: r2c slower than c2c at p={p} (timing noise?)");
        }
    }

    // Modeled at paper scale: the cost model's view of the same halving,
    // priced on the Perlmutter description (window 2, the default).
    let big = 256usize;
    let bspec = SphereSpec::new([big, big, big], 64.0, SphereKind::Centered);
    let boff = bspec.offsets();
    let m = Machine::perlmutter_a100();
    println!();
    println!("== modeled r2c at paper scale: cube 256^3, nb=256, sphere d=128 ({}) ==", m.name);
    println!("{:>5} {:>12} {:>12} {:>7}", "p", "c2c", "r2c", "ratio");
    let mut p = 4;
    while p <= 1024 {
        let c2c_cost = fftb::model::cost::planewave(&boff, 256, p, true);
        let r2c_cost = fftb::model::cost::planewave_r2c(&boff, 256, p);
        let c = price_stages(&c2c_cost, &m, 2);
        let r = price_stages(&r2c_cost, &m, 2);
        println!("{p:>5} {:>11.2}ms {:>11.2}ms {:>7.4}", c * 1e3, r * 1e3, r / c);
        assert!(r < c, "modeled r2c must beat c2c at p={p}");
        p *= 2;
    }
}

fn modeled_section() {
    let n = 256usize;
    let spec = SphereSpec::new([n, n, n], 64.0, SphereKind::Centered);
    let off = spec.offsets();
    let w = Workload { shape: [n, n, n], nb: 256, offsets: &off };
    let m = Machine::perlmutter_a100();

    println!();
    println!("== modeled at paper scale: cube 256^3, nb=256, sphere d=128 ({}) ==", m.name);
    println!(
        "{:>5} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "p",
        "slab-b",
        "slab-nb",
        "pencil-b",
        "pencil-nb",
        "planewave"
    );
    let mut p = 4;
    while p <= 1024 {
        let row = fig9_row(&w, p, &m);
        println!(
            "{p:>5} {:>11.2}ms {:>11.2}ms {:>11.2}ms {:>11.2}ms {:>11.2}ms",
            row[0] * 1e3,
            row[1] * 1e3,
            row[2] * 1e3,
            row[3] * 1e3,
            row[4] * 1e3
        );
        // The paper's two hard claims:
        assert!(row[0] < row[1], "batched must beat non-batched at p={p}");
        assert!(row[4] < row[0], "planewave must beat batched cube at p={p}");
        p *= 2;
    }
    // Near-linear planewave scaling 4 -> 1024 (paper: "scales almost
    // linear to 1024 GPUs").
    let t4 = fftb::model::project(Variant::PlaneWave, &w, 4, &m);
    let t1024 = fftb::model::project(Variant::PlaneWave, &w, 1024, &m);
    let speedup = t4 / t1024;
    println!("planewave speedup 4->1024: {speedup:.0}x (linear would be 256x)");
    assert!(speedup > 64.0, "planewave should scale well, got {speedup}");
}

fn main() {
    live_section();
    overlap_section();
    r2c_section();
    modeled_section();
    println!("fig9_scaling bench done");
}
