//! Integration tests of the tuner-driven distributed SCF loop
//! (`dft::scf::ScfRunner`): density conservation and SPMD bit-identity
//! across world sizes, the steady-state re-plan-free / allocation-free
//! contract (`ExecTrace::plan_cache_hit`, `alloc_bytes == 0`), and the
//! wisdom file round trip that seeds a second process life — including
//! the SCF-shaped probe record.

use std::sync::Arc;

use fftb::comm::run_world;
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::cyclic;
use fftb::tuner::{Probe, Wisdom};

const N: usize = 12;
const A: f64 = 8.0;
const ECUT: f64 = 2.0;
const NB: usize = 2;

fn opts(iters: usize) -> ScfOptions {
    // tol 0: run the full budget so every test sees the same iteration
    // count; coupling on so the loop is genuinely self-consistent.
    ScfOptions { max_iters: iters, tol: 0.0, coupling: 0.3, ..Default::default() }
}

fn pot() -> GaussianWells {
    GaussianWells::single(2.0, 1.4)
}

/// Run the loop on `p` ranks; per rank: (result, gathered-global-ready
/// local density, traces' (cache_hit, alloc) pairs).
#[allow(clippy::type_complexity)]
fn run_scf(p: usize, iters: usize) -> Vec<(Vec<f64>, Vec<f64>, Vec<(bool, u64)>, String, usize)> {
    run_world(p, move |comm| {
        let lat = Lattice::new(A, N, ECUT);
        let backend = RustFftBackend::new();
        let mut runner = ScfRunner::new(lat, NB, &pot(), &comm, &backend, opts(iters))
            .expect("plan_auto_scf must find a feasible plan");
        let res = runner.run(&backend);
        let flags = runner
            .drain_traces()
            .iter()
            .map(|t| (t.plan_cache_hit, t.alloc_bytes))
            .collect();
        // Scalars whose bits every rank must agree on.
        let mut scalars: Vec<f64> = res.eigenvalues.clone();
        for s in &res.history {
            scalars.push(s.charge);
            scalars.push(s.delta_rho);
            scalars.push(s.max_residual);
            scalars.push(s.energy.total);
            scalars.push(s.energy.hartree);
        }
        (scalars, res.density.rho, flags, res.plan_kind, res.window)
    })
}

/// Reassemble the global `[n, n, n]` density from per-rank z-slabs
/// (z cyclic over p ranks).
fn gather_rho(locals: &[Vec<f64>], p: usize) -> Vec<f64> {
    let mut global = vec![0.0; N * N * N];
    for z in 0..N {
        let r = cyclic::owner(z, p);
        let lz = cyclic::global_to_local(z, p);
        for y in 0..N {
            for x in 0..N {
                global[x + N * (y + N * z)] = locals[r][x + N * (y + N * lz)];
            }
        }
    }
    global
}

#[test]
fn density_conserved_and_bit_identical_across_ranks() {
    for p in [1usize, 2, 4] {
        let outs = run_scf(p, 3);
        // Charge conservation on every rank, every iteration (charges are
        // the first history scalars after the eigenvalues).
        for (scalars, _, _, kind, _) in &outs {
            for it in 0..3 {
                let charge = scalars[NB + 5 * it];
                assert!(
                    (charge - NB as f64).abs() < 1e-8,
                    "p={p} iter {it}: charge {charge}"
                );
            }
            assert_eq!(kind, "plane-wave", "p={p}");
        }
        // SPMD bit-identity: every global scalar — eigenvalues, charges,
        // density deltas, residuals — and the tuner decision must agree
        // across ranks to the last bit (allreduced quantities, identical
        // tuning inputs).
        let first = &outs[0];
        for (r, o) in outs.iter().enumerate().skip(1) {
            assert_eq!(o.0.len(), first.0.len());
            for (i, (a, b)) in o.0.iter().zip(&first.0).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "p={p} rank {r}: scalar {i} differs ({a} vs {b})"
                );
            }
            assert_eq!((&o.3, o.4), (&first.3, first.4), "p={p} rank {r}: decision differs");
        }
    }
}

#[test]
fn density_agrees_across_world_sizes() {
    // The same physics on p = 1, 2, 4 ranks: the assembled global density
    // must agree tightly (different decomposition, same transform).
    let rho1 = {
        let outs = run_scf(1, 3);
        gather_rho(&[outs[0].1.clone()], 1)
    };
    for p in [2usize, 4] {
        let outs = run_scf(p, 3);
        let locals: Vec<Vec<f64>> = outs.iter().map(|o| o.1.clone()).collect();
        let rho_p = gather_rho(&locals, p);
        let worst = rho1
            .iter()
            .zip(&rho_p)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        // Starting state is identical by construction (global-index
        // seeding); only summation-order fp noise separates the worlds.
        assert!(worst < 1e-7, "p={p}: global density diverges by {worst}");
    }
}

#[test]
fn steady_state_is_replan_free_and_allocation_free() {
    for p in [1usize, 2, 4] {
        let outs = run_scf(p, 4);
        for (r, (_, _, flags, _, _)) in outs.iter().enumerate() {
            assert_eq!(flags.len(), 5 * 4, "five transforms per iteration");
            // Iteration >= 2 (trace index >= 5): plans (band and Hartree)
            // served from the tuner's cache, zero workspace growth — the
            // acceptance pin, now covering the Hartree round trip too.
            for (i, (hit, alloc)) in flags.iter().enumerate().skip(5) {
                assert!(hit, "p={p} rank {r}: transform {i} executed a re-planned plan");
                assert_eq!(alloc, &0, "p={p} rank {r}: transform {i} grew its workspace");
            }
        }
    }
}

#[test]
fn wisdom_file_seeds_the_next_life_with_the_scf_probe() {
    let path = std::env::temp_dir().join("fftb_scf_test_wisdom.json");
    std::fs::remove_file(&path).ok();
    let p = 2;

    // First life: empirical SCF-shaped probe, wisdom written by rank 0.
    let path2 = path.clone();
    let first = run_world(p, move |comm| {
        let lat = Lattice::new(A, N, ECUT);
        let backend = RustFftBackend::new();
        let o = ScfOptions {
            empirical_top_k: 3,
            wisdom_path: Some(path2.clone()),
            ..opts(2)
        };
        let mut runner = ScfRunner::new(lat, NB, &pot(), &comm, &backend, o).unwrap();
        runner.run(&backend)
    });
    for r in &first {
        assert!(!r.from_wisdom, "first life must search");
        assert!(r.measured, "empirical_top_k=3 must measure the shortlist");
    }

    // The persisted record: a round-trip (`|rt`) signature carrying the
    // SCF probe kind and a positive measured time.
    let wisdom = Wisdom::load(&path).expect("rank 0 must have written the wisdom file");
    let sig = wisdom_sig(NB);
    let entry = wisdom.lookup(&sig).unwrap_or_else(|| panic!("no wisdom entry for `{sig}`"));
    assert_eq!(entry.probe, Probe::Scf, "the SCF-shaped probe must be recorded");
    assert!(entry.measured && entry.seconds > 0.0);
    // The runner's nb = 1 Hartree plan gets its own wisdom identity.
    let hsig = wisdom_sig(1);
    assert!(wisdom.lookup(&hsig).is_some(), "no wisdom entry for the Hartree plan `{hsig}`");

    // Second life: decision comes straight from the file.
    let path3 = path.clone();
    let second = run_world(p, move |comm| {
        let lat = Lattice::new(A, N, ECUT);
        let backend = RustFftBackend::new();
        let o = ScfOptions { wisdom_path: Some(path3.clone()), ..opts(2) };
        let mut runner = ScfRunner::new(lat, NB, &pot(), &comm, &backend, o).unwrap();
        runner.run(&backend)
    });
    std::fs::remove_file(&path).ok();
    for (f, s) in first.iter().zip(&second) {
        assert!(s.from_wisdom, "second life must decide from wisdom");
        assert!(!s.measured, "no re-measuring on a wisdom hit");
        assert_eq!((&s.plan_kind, s.window), (&f.plan_kind, f.window));
        assert!((s.density.charge - NB as f64).abs() < 1e-8);
    }
}

/// The round-trip request signature the runner tunes under for a given
/// band count (kept in sync with `TuneRequest::signature`).
fn wisdom_sig(nb: usize) -> String {
    let lat = Lattice::new(A, N, ECUT);
    let off = Arc::clone(&lat.offsets);
    format!(
        "{N}x{N}x{N}|nb={nb}|p=2|sphere:{}:{:016x}|rt",
        off.total(),
        off.fingerprint()
    )
}

#[test]
fn stale_wisdom_is_skipped_not_fatal() {
    // A version-1 (stale) wisdom file must not panic the runner — it
    // falls back to a fresh search and still completes.
    let path = std::env::temp_dir().join("fftb_scf_test_stale_wisdom.json");
    std::fs::write(
        &path,
        r#"{"version": 1, "entries": {"junk": {"kind": "plane-wave", "window": 1, "seconds": 1}}}"#,
    )
    .unwrap();
    let path2 = path.clone();
    let outs = run_world(2, move |comm| {
        let lat = Lattice::new(A, N, ECUT);
        let backend = RustFftBackend::new();
        let o = ScfOptions { wisdom_path: Some(path2.clone()), ..opts(2) };
        let mut runner = ScfRunner::new(lat, NB, &pot(), &comm, &backend, o).unwrap();
        runner.run(&backend)
    });
    for r in &outs {
        assert!(!r.from_wisdom, "stale wisdom must be ignored");
        assert!((r.density.charge - NB as f64).abs() < 1e-8);
    }
    // The run then overwrites the stale file with a current-version one.
    assert!(Wisdom::load(&path).is_ok(), "the stale file must be replaced");
    std::fs::remove_file(&path).ok();
}
