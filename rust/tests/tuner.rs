//! Integration tests of the autotuning planner subsystem (`fftb::tuner`):
//! plan-cache hit/miss semantics, SPMD determinism (all ranks derive the
//! same candidate from identical inputs, with and without live
//! measurement), wisdom round-trips through `util::json`, and the
//! regression that `plan_auto` never picks an infeasible pencil
//! factorization — prime rank counts included.

use std::sync::Arc;

use fftb::comm::run_world;
use fftb::fft::complex::{max_abs_diff, ZERO};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::plan::{Fftb, FftbOptions, PlanKind};
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::tuner::{Calibration, Tuner, Wisdom};

/// Run one auto-planned transform end to end and return what the tuner
/// chose plus proof of execution (output length).
fn auto_roundtrip(
    shape: [usize; 3],
    nb: usize,
    sphere: Option<Arc<fftb::fftb::sphere::OffsetArray>>,
    p: usize,
) -> Vec<(String, usize, usize)> {
    run_world(p, move |comm| {
        let mut tuner = Tuner::local();
        let backend = RustFftBackend::new();
        let tuned = Fftb::plan_auto(shape, nb, sphere.clone(), &comm, &mut tuner, None)
            .expect("plan_auto must find a feasible plan");
        let input = vec![ZERO; tuned.plan.input_len()];
        let (out, _) = tuned.plan.execute(&backend, input, Direction::Forward);
        let out_len = out.len();
        tuned.plan.recycle(out);
        (tuned.choice.kind.label(), tuned.choice.window, out_len)
    })
}

#[test]
fn plan_auto_cube_all_ranks_agree() {
    let outs = auto_roundtrip([8, 8, 8], 2, None, 4);
    let first = outs[0].clone();
    for (r, o) in outs.iter().enumerate() {
        assert_eq!((&o.0, o.1), (&first.0, first.1), "rank {r} disagrees with rank 0");
        assert!(o.2 > 0, "rank {r} produced no output");
    }
}

#[test]
fn plan_auto_noncube_all_ranks_agree() {
    // nx < p rules the 1D-grid plans out; the tuner must fall back to a
    // feasible pencil factorization.
    let outs = auto_roundtrip([4, 8, 16], 2, None, 6);
    let first = outs[0].clone();
    for o in &outs {
        assert_eq!((&o.0, o.1), (&first.0, first.1));
    }
    assert!(first.0.starts_with("pencil:"), "expected a pencil plan, got {}", first.0);
}

#[test]
fn plan_auto_prime_p_never_picks_infeasible_factorization() {
    // p = 7 is prime: the only pencil factorizations are 1x7 and 7x1, and
    // with nx = 4 the 7x1 grid (and every 1D-grid plan) is infeasible.
    // plan_auto must still return a working plan on every rank.
    let outs = auto_roundtrip([4, 8, 8], 1, None, 7);
    for o in &outs {
        assert_eq!(o.0, "pencil:1x7");
    }
}

#[test]
fn plan_auto_sphere_picks_planewave() {
    let n = 16;
    let spec = SphereSpec::new([n, n, n], n as f64 / 4.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let outs = auto_roundtrip([n, n, n], 2, Some(off), 2);
    for o in &outs {
        assert_eq!(o.0, "plane-wave", "staged padding must beat pad-to-cube");
    }
}

#[test]
fn distinct_spheres_never_share_plans_or_wisdom() {
    // Two different offset arrays (centered vs wrapped conventions) can
    // retain similar or equal point counts; the structural fingerprint in
    // the request signature must keep their plans and wisdom apart.
    let n = 8usize;
    let c = Arc::new(SphereSpec::new([n, n, n], 3.0, SphereKind::Centered).offsets());
    let w = Arc::new(SphereSpec::new([n, n, n], 3.0, SphereKind::Wrapped).offsets());
    assert_ne!(c.fingerprint(), w.fingerprint(), "different spheres, different prints");
    run_world(2, move |comm| {
        let mut tuner = Tuner::local();
        let a = tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&c)), &comm, None).unwrap();
        let b = tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&w)), &comm, None).unwrap();
        assert!(!b.cache_hit, "a different sphere must not be served the cached plan");
        assert!(!b.from_wisdom, "nor the other sphere's wisdom entry");
        assert!(!Arc::ptr_eq(&a.plan, &b.plan));
    });
}

#[test]
fn kpoint_offset_spheres_separate_plans_and_wisdom() {
    // Γ-offset spheres reduce exactly to the plain sphere (same
    // fingerprint → the same wisdom entry and cached plan object), while
    // every distinct k gets its own plan-cache and wisdom identity — even
    // when the shift moves no grid point across the cutoff.
    let n = 8usize;
    let spec = SphereSpec::new([n, n, n], 3.0, SphereKind::Wrapped);
    let gamma = Arc::new(spec.offsets());
    let gamma_off = Arc::new(spec.offset([0.0; 3]));
    assert_eq!(gamma.fingerprint(), gamma_off.fingerprint(), "Γ must reduce exactly");
    let k1 = Arc::new(spec.offset([0.25, 0.0, 0.0]));
    let k2 = Arc::new(spec.offset([0.0, 0.25, 0.0]));
    assert_ne!(k1.fingerprint(), gamma.fingerprint());
    assert_ne!(k1.fingerprint(), k2.fingerprint());
    run_world(2, move |comm| {
        let mut tuner = Tuner::local();
        let a = tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&gamma)), &comm, None).unwrap();
        let b =
            tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&gamma_off)), &comm, None).unwrap();
        assert!(b.cache_hit, "the Γ-offset sphere must be served the plain sphere's plan");
        assert!(b.from_wisdom, "and its wisdom entry");
        assert!(Arc::ptr_eq(&a.plan, &b.plan));
        let c = tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&k1)), &comm, None).unwrap();
        assert!(!c.cache_hit && !c.from_wisdom, "a shifted k must plan afresh");
        let d = tuner.plan_auto([n, n, n], 1, Some(Arc::clone(&k2)), &comm, None).unwrap();
        assert!(!d.cache_hit && !d.from_wisdom, "each k separately");
        assert!(!Arc::ptr_eq(&c.plan, &d.plan));
        assert_eq!(tuner.cache.len(), 3, "Γ + two k-points = three cached plans");
    });
}

#[test]
fn real_requests_get_their_own_wisdom_and_plans() {
    // plan_auto_real must never share plan-cache or wisdom state with a
    // complex request on the same sphere: the signatures differ (`|r2c`),
    // the PlanKey carries the transform tag, and the winning kind is the
    // half-spectrum family.
    let n = 8usize;
    let spec = SphereSpec::new([n, n, n], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    run_world(2, move |comm| {
        let mut tuner = Tuner::local();
        let c2c = tuner.plan_auto([n, n, n], 2, Some(Arc::clone(&off)), &comm, None).unwrap();
        let r2c = tuner.plan_auto_real([n, n, n], 2, Arc::clone(&off), &comm, None).unwrap();
        assert!(!r2c.cache_hit, "real requests must not be served the complex plan");
        assert!(!r2c.from_wisdom, "nor the complex wisdom entry");
        assert_eq!(r2c.choice.kind.label(), "plane-wave-r2c");
        assert!(!Arc::ptr_eq(&c2c.plan, &r2c.plan));
        assert_eq!(tuner.cache.len(), 2);
        // Repeat real request: hits the r2c plan and wisdom, not the c2c.
        let again = tuner.plan_auto_real([n, n, n], 2, Arc::clone(&off), &comm, None).unwrap();
        assert!(again.cache_hit && again.from_wisdom);
        assert!(Arc::ptr_eq(&again.plan, &r2c.plan));
        // The r2c plan executes end to end through the embedded adapter.
        let backend = RustFftBackend::new();
        let input = vec![ZERO; r2c.plan.input_len()];
        let (out, _) = r2c.plan.execute(&backend, input, Direction::Forward);
        assert_eq!(out.len(), r2c.plan.output_len());
        r2c.plan.recycle(out);
    });
}

#[test]
fn plan_auto_repeat_hits_cache_and_wisdom() {
    run_world(2, |comm| {
        let mut tuner = Tuner::local();
        let a = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(!a.cache_hit, "first call must build");
        assert!(!a.from_wisdom, "first call must search");
        let b = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(b.cache_hit, "second call must be served from the plan cache");
        assert!(b.from_wisdom, "second call must reuse the recorded decision");
        assert!(Arc::ptr_eq(&a.plan, &b.plan), "cache hit must return the same plan");
        assert_eq!(a.choice.kind, b.choice.kind);
        assert_eq!(a.choice.window, b.choice.window);
        // A different batch count is a different plan.
        let c = tuner.plan_auto([8, 8, 8], 3, None, &comm, None).unwrap();
        assert!(!c.cache_hit);
    });
}

#[test]
fn wisdom_survives_a_restart() {
    // First process life: tune, save wisdom. Second life: load wisdom,
    // same request — decision comes from the file, no fresh search.
    let path = std::env::temp_dir().join("fftb_tuner_wisdom_roundtrip.json");
    let saved: Vec<Wisdom> = run_world(2, |comm| {
        let mut tuner = Tuner::local();
        // A hand-written calibration record (the live probes are exercised
        // by the unit tests in tuner::calibrate).
        tuner.wisdom.calibration = Some(Calibration {
            fft_flops_per_sec: 3.0e9,
            mem_bw: 1.0e10,
            alpha: 2.0e-7,
            beta: 2.0e-10,
        });
        tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        tuner.wisdom.clone()
    });
    saved[0].save(&path).unwrap();
    let loaded = Wisdom::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, saved[0]);
    assert!(loaded.calibration.is_some(), "calibration must persist");

    let outs = run_world(2, move |comm| {
        let mut tuner = Tuner::with_wisdom(fftb::model::Machine::local_cpu(), loaded.clone());
        let tuned = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        (tuned.from_wisdom, tuned.choice.kind.label(), tuned.choice.window)
    });
    let first: Vec<_> = run_world(2, |comm| {
        let mut tuner = Tuner::local();
        let t = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        (t.choice.kind.label(), t.choice.window)
    });
    for o in &outs {
        assert!(o.0, "decision must come from loaded wisdom");
        assert_eq!((&o.1, o.2), (&first[0].0, first[0].1), "wisdom must reproduce the choice");
    }
}

#[test]
fn empirical_mode_all_ranks_agree() {
    let outs = run_world(4, |comm| {
        let mut tuner = Tuner::local();
        tuner.empirical_top_k = 3;
        let backend = RustFftBackend::new();
        let tuned = tuner
            .plan_auto([8, 8, 8], 2, None, &comm, Some(&backend))
            .expect("empirical plan_auto must succeed");
        assert!(tuned.measured, "empirical mode must measure");
        // The winner must execute.
        let input = vec![ZERO; tuned.plan.input_len()];
        let (out, _) = tuned.plan.execute(&backend, input, Direction::Forward);
        tuned.plan.recycle(out);
        // Re-request: the measured decision is wisdom now, no re-measuring.
        let again = tuner.plan_auto([8, 8, 8], 2, None, &comm, Some(&backend)).unwrap();
        assert!(again.from_wisdom && !again.measured);
        (tuned.choice.kind.label(), tuned.choice.window)
    });
    for o in &outs {
        assert_eq!(o, &outs[0], "empirical winners must agree across ranks");
    }
}

#[test]
fn wisdom_v3_lifecycle_survives_a_restart() {
    // The v3 lifecycle fields — the per-entry `loads` counter and the
    // `measured_at` provenance stamp — must survive the on-disk round
    // trip exactly, and the file must carry the current format version.
    let sig = "8x8x8|nb=2|p=2|dense";
    let path = std::env::temp_dir().join("fftb_tuner_wisdom_v3_lifecycle.json");
    let saved: Vec<Wisdom> = run_world(2, |comm| {
        let mut tuner = Tuner::local();
        let first = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(!first.from_wisdom, "the first request must search");
        for _ in 0..3 {
            let again = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
            assert!(again.from_wisdom, "repeat requests must be wisdom-steered");
        }
        tuner.wisdom.clone()
    });
    let e = saved[0].lookup(sig).expect("the tuned request must be remembered");
    assert_eq!(e.loads, 3, "each wisdom-steered request counts one load");
    assert!(e.measured_at > 0.0, "recording must stamp provenance");
    saved[0].save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let loaded = Wisdom::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(
        text.contains("\"version\": 4") || text.contains("\"version\":4"),
        "the file must carry the current format version: {text}"
    );
    let back = loaded.lookup(sig).unwrap();
    assert_eq!(back.loads, e.loads, "loads must survive the restart");
    assert_eq!(
        back.measured_at.to_bits(),
        e.measured_at.to_bits(),
        "measured_at must survive the restart bit-exactly"
    );
}

#[test]
fn stale_v2_wisdom_upgrades_in_place_and_keeps_steering() {
    // A version-2 file (pre-lifecycle format) must load with fresh
    // lifecycle fields, steer the next request like native wisdom, count
    // that load, and re-save at version 3 — the in-place upgrade.
    let sig = "8x8x8|nb=2|p=2|dense";
    let path = std::env::temp_dir().join("fftb_tuner_wisdom_v2_upgrade.json");
    let v2 = r#"{"version": 2, "entries": {"8x8x8|nb=2|p=2|dense":
        {"kind": "slab-pencil", "window": 2, "seconds": 0.001}}}"#;
    std::fs::write(&path, v2).unwrap();
    let loaded = Wisdom::load(&path).unwrap();
    let e = loaded.lookup(sig).unwrap();
    assert_eq!((e.loads, e.measured_at), (0, 0.0), "v2 entries get fresh lifecycle fields");

    let upgraded: Vec<Wisdom> = run_world(2, move |comm| {
        let mut tuner = Tuner::with_wisdom(fftb::model::Machine::local_cpu(), loaded.clone());
        let t = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(t.from_wisdom, "upgraded wisdom must keep steering");
        assert_eq!(t.choice.kind.label(), "slab-pencil");
        assert_eq!(t.choice.window, 2);
        tuner.wisdom.clone()
    });
    assert_eq!(upgraded[0].lookup(sig).unwrap().loads, 1, "the steered request counts");
    upgraded[0].save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(
        text.contains("\"version\": 4") || text.contains("\"version\":4"),
        "re-saving must upgrade the file to the current version: {text}"
    );
}

#[test]
fn remeasure_after_retires_hot_entries_in_lockstep() {
    // The wisdom lifecycle for long-lived services: once an entry has
    // steered `remeasure_after` requests it is retired, and the next
    // request runs a fresh search instead of trusting the remembered
    // winner forever — identically on every rank, with the plan cache
    // still serving the same plan object across the re-measure.
    run_world(2, |comm| {
        let mut tuner = Tuner::local();
        tuner.remeasure_after = 2;
        let sig = "8x8x8|nb=2|p=2|dense";
        let first = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(!first.from_wisdom);
        for _ in 0..2 {
            assert!(tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap().from_wisdom);
        }
        assert_eq!(tuner.wisdom.lookup(sig).unwrap().loads, 2);
        // The entry hit the threshold: the next request retires it and
        // searches afresh (recording a new entry with a reset counter),
        // while the re-search lands on the same cached plan object.
        let refreshed = tuner.plan_auto([8, 8, 8], 2, None, &comm, None).unwrap();
        assert!(!refreshed.from_wisdom, "a hot entry must be retired and re-searched");
        assert!(
            Arc::ptr_eq(&refreshed.plan, &first.plan),
            "the re-search must land on the same cached plan"
        );
        assert_eq!(tuner.wisdom.lookup(sig).unwrap().loads, 0, "the new entry starts fresh");
    });
}

#[test]
fn auto_window_options_match_default_numerics() {
    // FftbOptions::auto() frees only the window; the windowed exchange is
    // bit-identical across windows, so the auto plan must agree exactly
    // with the default plan.
    let n = 8usize;
    let p = 2usize;
    let errs = run_world(p, move |comm| {
        let grid = fftb::fftb::grid::ProcGrid::new(&[p], comm).unwrap();
        let dom = || {
            fftb::fftb::domain::Domain::new(vec![0, 0, 0], vec![n as i64 - 1; 3]).unwrap()
        };
        let mk = |layout: &str| {
            fftb::fftb::tensor::DistTensor::zeros(
                fftb::fftb::domain::DomainList::new(vec![dom()]).unwrap(),
                layout,
                Arc::clone(&grid),
            )
            .unwrap()
        };
        let (ti, to) = (mk("x{0} y z"), mk("X Y Z{0}"));
        let auto = Fftb::plan_opt(
            [n, n, n],
            &to,
            "X Y Z",
            &ti,
            "x y z",
            Arc::clone(&grid),
            FftbOptions::auto(),
        )
        .unwrap();
        assert!(matches!(auto.kind, PlanKind::SlabPencil(_)));
        let plain =
            Fftb::plan([n, n, n], &to, "X Y Z", &ti, "x y z", Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = fftb::fftb::plan::testutil::phased(auto.input_len(), 11);
        let (a, _) = auto.execute(&backend, input.clone(), Direction::Forward);
        let (b, _) = plain.execute(&backend, input, Direction::Forward);
        max_abs_diff(&a, &b)
    });
    for e in errs {
        assert_eq!(e, 0.0, "window choice must never change results");
    }
}
