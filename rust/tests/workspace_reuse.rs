//! The plan-once / execute-many property: every plan precomputes its
//! communication schedules and owns reusable workspaces, so steady-state
//! `execute()` calls perform zero heap allocation in the pack/unpack/FFT
//! stages. `ExecTrace::alloc_bytes` records workspace growth per execution;
//! these tests assert it is non-zero on the first call (the counter works)
//! and exactly zero once the workspaces have reached their high-water mark
//! — for all five plan kinds, through repeated forward/inverse round trips
//! (the SCF-loop pattern Fig. 9 measures).
//!
//! All five plans run the *overlapped* windowed exchange by default
//! (window 2), so every assertion below already covers the overlapped
//! path; the explicit window tests at the bottom pin the property for the
//! serial-ordering (window 1) and full-window (p-1) extremes too.

use std::sync::Arc;

use fftb::fft::complex::max_abs_diff;
use fftb::fft::dft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{
    ExecTrace, Fftb, NonBatchedLoop, PaddedSpherePlan, PencilPlan, PlanKind, PlaneWavePlan,
    RealPlaneWavePlan, SlabPencilPlan,
};
use fftb::fftb::sphere::{SphereKind, SphereSpec};

const ROUND_TRIPS: usize = 3;

/// Drive `forward`/`inverse` through `ROUND_TRIPS` alternating round trips,
/// threading the returned buffers back in (the steady-state call pattern).
/// Returns the per-call alloc_bytes, in call order.
fn drive<F, I>(input: Vec<fftb::fft::complex::Complex>, mut forward: F, mut inverse: I) -> Vec<u64>
where
    F: FnMut(Vec<fftb::fft::complex::Complex>) -> (Vec<fftb::fft::complex::Complex>, ExecTrace),
    I: FnMut(Vec<fftb::fft::complex::Complex>) -> (Vec<fftb::fft::complex::Complex>, ExecTrace),
{
    let original = input.clone();
    let mut allocs = Vec::new();
    let mut buf = input;
    for it in 0..ROUND_TRIPS {
        let (spec, tr_f) = forward(buf);
        allocs.push(tr_f.alloc_bytes);
        let (back, tr_i) = inverse(spec);
        allocs.push(tr_i.alloc_bytes);
        let err = max_abs_diff(&back, &original);
        assert!(err < 1e-8, "round trip {it} drifted: err={err}");
        buf = back;
    }
    allocs
}

/// First call must have grown the workspace; every call from the second
/// round trip on must be allocation-free.
fn assert_steady_state(allocs: &[u64], label: &str) {
    assert!(allocs[0] > 0, "{label}: first execute should grow the workspace");
    for (i, &a) in allocs.iter().enumerate().skip(2) {
        assert_eq!(a, 0, "{label}: call {i} allocated {a} bytes in steady state");
    }
}

#[test]
fn slab_pencil_steady_state_is_allocation_free() {
    let shape = [8usize, 8, 8];
    let (nb, p) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p, |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "slab-pencil");
        // Cube shapes: even the very first inverse reuses what the first
        // forward grew.
        assert_eq!(allocs[1], 0, "slab-pencil: first inverse should already be warm");
    }
}

#[test]
fn slab_pencil_repeated_forward_is_allocation_free() {
    // Forward-only repetition (the bench pattern): caller hands a fresh
    // input-sized vector every call; on cube shapes nothing grows after
    // call one.
    let shape = [8usize, 8, 8];
    let (nb, p) = (2usize, 2usize);
    fftb::comm::run_world(p, |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), 3);
        for it in 0..3 {
            let (_, tr) = plan.forward(&backend, input.clone());
            if it > 0 {
                assert_eq!(tr.alloc_bytes, 0, "forward #{it} allocated");
            }
        }
    });
}

#[test]
fn non_batched_loop_steady_state_is_allocation_free() {
    let shape = [8usize, 8, 8];
    let (nb, p) = (3usize, 2usize);
    let allocs_all = fftb::comm::run_world(p, |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = NonBatchedLoop::new(shape, nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "non-batched loop");
    }
}

#[test]
fn pencil_steady_state_is_allocation_free() {
    let shape = [8usize, 8, 8];
    let nb = 2usize;
    let (p0, p1) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p0 * p1, |comm| {
        let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
        let plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "pencil");
    }
}

#[test]
fn planewave_steady_state_is_allocation_free() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "plane-wave");
    }
}

#[test]
fn overlapped_full_window_stays_allocation_free() {
    // The exchange window changes only message scheduling; no window size
    // may reintroduce steady-state allocation.
    let shape = [8usize, 8, 8];
    let (nb, p) = (2usize, 4usize);
    for window in [1usize, 3] {
        let allocs_all = fftb::comm::run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let mut plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(fftb::comm::CommTuning::with_window(window));
            let backend = RustFftBackend::new();
            let input = phased(plan.input_len(), grid.rank() as u64);
            drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
        });
        for allocs in &allocs_all {
            assert_steady_state(allocs, "slab-pencil (explicit window)");
        }
    }
}

#[test]
fn overlapped_pencil_window_stays_allocation_free() {
    let shape = [8usize, 8, 8];
    let nb = 2usize;
    let (p0, p1) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p0 * p1, |comm| {
        let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
        let mut plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        plan.set_tuning(fftb::comm::CommTuning::with_window(3));
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "pencil (explicit window)");
    }
}

#[test]
fn noncube_unequal_extents_alternating_is_allocation_free() {
    // [5, 4, 6] on p = 2: local input and output extents differ on every
    // rank (ceil vs floor of the cyclic splits). The single recycled result
    // slot used to regrow the caller's vector once per direction change;
    // the size-classed slot pool keeps one buffer per class instead.
    let shape = [5usize, 4, 6];
    let (nb, p) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p, |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        assert_ne!(
            plan.input_len(),
            plan.output_len(),
            "shape chosen to have unequal local extents"
        );
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "slab-pencil (non-cube, unequal extents)");
    }
}

#[test]
fn forward_only_sphere_with_recycle_is_allocation_free() {
    // The forward-only G→r pattern: the caller consumes each dense cube
    // and hands the storage back via `recycle`. The pool then serves every
    // later forward without minting a cube (previously impossible: the
    // caller kept the output, so the plan re-minted per call).
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 2usize);
    fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        for it in 0..4 {
            let (cube, tr) = plan.forward(&backend, input.clone());
            if it > 0 {
                assert_eq!(tr.alloc_bytes, 0, "forward #{it} allocated with recycling on");
            }
            plan.recycle(cube);
        }
    });
}

#[test]
fn forward_only_padded_sphere_with_recycle_is_allocation_free() {
    // Same contract for the pad-to-cube baseline: its cube-sized storage
    // circulates through the inner slab plan's pool, where recycled
    // outputs land.
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 2usize);
    fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = PaddedSpherePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        for it in 0..4 {
            let (cube, tr) = plan.forward(&backend, input.clone());
            if it > 0 {
                assert_eq!(tr.alloc_bytes, 0, "forward #{it} allocated with recycling on");
            }
            plan.recycle(cube);
        }
    });
}

#[test]
fn inverse_only_padded_sphere_with_recycle_is_allocation_free() {
    // The r→G-only pattern on the baseline plan: packed outputs recycled
    // by the caller must serve the truncation stage of later inverses.
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 2usize);
    fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = PaddedSpherePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let cube = phased(plan.output_len(), grid.rank() as u64);
        for it in 0..4 {
            let (packed, tr) = plan.inverse(&backend, cube.clone());
            if it > 0 {
                assert_eq!(tr.alloc_bytes, 0, "inverse #{it} allocated with recycling on");
            }
            plan.recycle(packed);
        }
    });
}

#[test]
fn forward_only_noncube_with_recycle_is_allocation_free() {
    // Same recycling contract on a dense plan whose output is *larger*
    // than its input on some ranks.
    let shape = [5usize, 4, 6];
    let (nb, p) = (2usize, 2usize);
    fftb::comm::run_world(p, |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        for it in 0..4 {
            let (out, tr) = plan.forward(&backend, input.clone());
            if it > 0 {
                assert_eq!(tr.alloc_bytes, 0, "forward #{it} allocated with recycling on");
            }
            plan.recycle(out);
        }
    });
}

/// The `execute_into` contract, pinned once per plan kind: results are
/// bit-identical to the owned-storage `execute` adapter, and in steady
/// state (workspaces warm, slot pool seeded) *both* entry points report
/// `alloc_bytes == 0` — including `take_buffer`, the pool-staging half of
/// the pairing callers use for long-lived output storage.
fn pin_execute_into_matches_execute(
    plan: &Fftb,
    backend: &RustFftBackend,
    seed: u64,
    label: &str,
) {
    // Warm both directions once through the owned-storage adapter.
    let inp = phased(plan.input_len(), seed);
    let (cube, _) = plan.execute(backend, inp.clone(), Direction::Forward);
    let (back, _) = plan.execute(backend, cube, Direction::Inverse);
    plan.recycle(back);
    // Seed one spare buffer per size class so the two entry points can
    // hold checked-out storage simultaneously without minting.
    plan.recycle(phased(plan.input_len(), 0));
    plan.recycle(phased(plan.output_len(), 0));

    let mut fwd_out: Vec<fftb::fft::complex::Complex> = Vec::new();
    for dir in [Direction::Forward, Direction::Inverse] {
        let out_len = match dir {
            Direction::Forward => plan.output_len(),
            Direction::Inverse => plan.input_len(),
        };
        // The inverse leg consumes the forward leg's spectrum so sphere
        // plans see well-formed coefficients in both directions.
        let src = if dir == Direction::Forward { inp.clone() } else { fwd_out.clone() };

        let (mut out_b, grew) = plan.take_buffer(out_len);
        assert_eq!(grew, 0, "{label} {dir:?}: take_buffer minted after warmup");
        let tr_b = plan.execute_into(backend, &src, &mut out_b, dir);
        assert_eq!(tr_b.alloc_bytes, 0, "{label} {dir:?}: execute_into allocated");

        let (out_a, tr_a) = plan.execute(backend, src.clone(), dir);
        assert_eq!(tr_a.alloc_bytes, 0, "{label} {dir:?}: execute allocated");

        assert_eq!(out_a.len(), out_b.len(), "{label} {dir:?}: length mismatch");
        for (i, (a, b)) in out_a.iter().zip(&out_b).enumerate() {
            assert_eq!(
                (a.re.to_bits(), a.im.to_bits()),
                (b.re.to_bits(), b.im.to_bits()),
                "{label} {dir:?}: element {i} differs ({a:?} vs {b:?})"
            );
        }
        if dir == Direction::Forward {
            fwd_out = out_a.clone();
        }
        plan.recycle(out_a);
        plan.recycle(out_b);
    }
}

#[test]
fn execute_into_is_bit_identical_and_allocation_free_on_1d_grid_kinds() {
    let shape = [8usize, 8, 8];
    let (nb, p) = (2usize, 2usize);
    let spec = SphereSpec::new(shape, 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let seed = grid.rank() as u64;
        let kinds: Vec<(Fftb, &str)> = vec![
            (
                Fftb {
                    kind: PlanKind::SlabPencil(
                        SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap(),
                    ),
                    sizes: shape,
                    nb,
                },
                "slab-pencil",
            ),
            (
                Fftb {
                    kind: PlanKind::SlabPencilLoop(
                        NonBatchedLoop::new(shape, nb, Arc::clone(&grid)).unwrap(),
                    ),
                    sizes: shape,
                    nb,
                },
                "non-batched loop",
            ),
            (
                Fftb {
                    kind: PlanKind::PlaneWave(
                        PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap(),
                    ),
                    sizes: shape,
                    nb,
                },
                "plane-wave",
            ),
            (
                Fftb {
                    kind: PlanKind::PaddedSphere(
                        PaddedSpherePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap(),
                    ),
                    sizes: shape,
                    nb,
                },
                "padded-sphere",
            ),
            (
                Fftb {
                    kind: PlanKind::PlaneWaveR2c(
                        RealPlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap(),
                    ),
                    sizes: shape,
                    nb,
                },
                "plane-wave r2c",
            ),
        ];
        for (plan, label) in &kinds {
            pin_execute_into_matches_execute(plan, &backend, seed, label);
        }
    });
}

#[test]
fn execute_into_is_bit_identical_and_allocation_free_on_pencil() {
    let shape = [8usize, 8, 8];
    let nb = 2usize;
    let (p0, p1) = (2usize, 2usize);
    fftb::comm::run_world(p0 * p1, |comm| {
        let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
        let backend = RustFftBackend::new();
        let seed = grid.rank() as u64;
        let plan = Fftb {
            kind: PlanKind::Pencil(PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap()),
            sizes: shape,
            nb,
        };
        pin_execute_into_matches_execute(&plan, &backend, seed, "pencil");
    });
}

#[test]
fn padded_sphere_steady_state_is_allocation_free() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Centered);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 2usize);
    let allocs_all = fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = PaddedSpherePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
        let backend = RustFftBackend::new();
        let input = phased(plan.input_len(), grid.rank() as u64);
        drive(input, |v| plan.forward(&backend, v), |v| plan.inverse(&backend, v))
    });
    for allocs in &allocs_all {
        assert_steady_state(allocs, "padded-sphere");
    }
}
