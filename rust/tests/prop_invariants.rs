//! Property-based tests (hand-rolled harness: no proptest in the offline
//! dependency set — `fftb::util::prng` drives randomized cases with
//! deterministic seeds, so failures are reproducible by seed).
//!
//! Each property runs across a randomized family of sizes, rank counts,
//! batch sizes and sphere radii.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::coordinator::{BatchingDriver, TransformJob};
use fftb::fft::batch::Fft1d;
use fftb::fft::complex::{max_abs_diff, Complex, ZERO};
use fftb::fft::dft::{naive_dft, Direction};
use fftb::fftb::grid::{cyclic, ProcGrid};
use fftb::fftb::layout::Layout;
use fftb::fft::real::{irfft, rfft};
use fftb::fftb::plan::testutil::{gather_cube_z, phased, scatter_cube_x};
use fftb::fftb::plan::{PlaneWavePlan, RealPlaneWavePlan, SlabPencilPlan};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::sphere::{OffsetArray, SphereKind, SphereSpec};
use fftb::util::prng::Prng;

const CASES: usize = 25;

#[test]
fn prop_fft_matches_naive_dft_any_size() {
    let mut rng = Prng::new(0xF0F0);
    for case in 0..CASES {
        let n = 1 + rng.next_below(96);
        let x = rng.complex_vec(n);
        let dir = if rng.next_f64() < 0.5 { Direction::Forward } else { Direction::Inverse };
        let want = naive_dft(&x, dir);
        let plan = Fft1d::new(n, dir);
        let mut got = x.clone();
        plan.run_batch_alloc(&mut got);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-8 * n as f64, "case {case}: n={n} dir={dir:?} err={err}");
    }
}

#[test]
fn prop_fft_round_trip_and_linearity() {
    let mut rng = Prng::new(0xBEEF);
    for case in 0..CASES {
        let n = 2 + rng.next_below(64);
        let x = rng.complex_vec(n);
        let y = rng.complex_vec(n);
        let a = Complex::new(rng.next_signed(), rng.next_signed());
        let fwd = Fft1d::new(n, Direction::Forward);
        let inv = Fft1d::new(n, Direction::Inverse);

        // Round trip.
        let mut rt = x.clone();
        fwd.run_batch_alloc(&mut rt);
        inv.run_batch_alloc(&mut rt);
        assert!(max_abs_diff(&rt, &x) < 1e-9, "case {case}: round trip n={n}");

        // Linearity: F(a x + y) = a F(x) + F(y).
        let mut lhs: Vec<Complex> =
            x.iter().zip(&y).map(|(xv, yv)| a * *xv + *yv).collect();
        fwd.run_batch_alloc(&mut lhs);
        let mut fx = x.clone();
        fwd.run_batch_alloc(&mut fx);
        let mut fy = y.clone();
        fwd.run_batch_alloc(&mut fy);
        let rhs: Vec<Complex> = fx.iter().zip(&fy).map(|(xv, yv)| a * *xv + *yv).collect();
        assert!(max_abs_diff(&lhs, &rhs) < 1e-8 * n as f64, "case {case}: linearity n={n}");
    }
}

#[test]
fn prop_parseval() {
    let mut rng = Prng::new(0x1234);
    for _ in 0..CASES {
        let n = 1 + rng.next_below(128);
        let x = rng.complex_vec(n);
        let mut fx = x.clone();
        Fft1d::new(n, Direction::Forward).run_batch_alloc(&mut fx);
        let ex: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let ef: f64 = fx.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        assert!((ex - ef).abs() < 1e-8 * ex.max(1.0), "n={n}");
    }
}

#[test]
fn prop_cyclic_distribution_partition() {
    let mut rng = Prng::new(0x5555);
    for _ in 0..100 {
        let n = 1 + rng.next_below(500);
        let p = 1 + rng.next_below(16);
        let total: usize = (0..p).map(|r| cyclic::local_count(n, p, r)).sum();
        assert_eq!(total, n);
        let g = rng.next_below(n);
        let owner = cyclic::owner(g, p);
        let l = cyclic::global_to_local(g, p);
        assert_eq!(cyclic::local_to_global(l, p, owner), g);
        assert!(l < cyclic::local_count(n, p, owner));
    }
}

#[test]
fn prop_layout_parse_round_trip() {
    let mut rng = Prng::new(0x777);
    let names = ["x", "y", "z", "b", "w", "q1", "dim_a"];
    for _ in 0..50 {
        let ndim = 1 + rng.next_below(5);
        let mut used = Vec::new();
        let mut axes_used = Vec::new();
        let mut toks = Vec::new();
        for _ in 0..ndim {
            let name = loop {
                let c = *rng.choose(&names);
                if !used.contains(&c) {
                    break c;
                }
            };
            used.push(name);
            if rng.next_f64() < 0.4 {
                let axis = loop {
                    let a = rng.next_below(3);
                    if !axes_used.contains(&a) {
                        break a;
                    }
                };
                axes_used.push(axis);
                toks.push(format!("{name}{{{axis}}}"));
            } else {
                toks.push(name.to_string());
            }
        }
        let s = toks.join(" ");
        let l = Layout::parse(&s).expect("generated layouts must parse");
        assert_eq!(l.to_string_form(), s);
        assert_eq!(l.ndim(), ndim);
    }
}

#[test]
fn prop_sphere_offsets_consistent() {
    let mut rng = Prng::new(0x9999);
    for _ in 0..15 {
        let n = 6 + 2 * rng.next_below(8); // 6..20
        let radius = 1.0 + rng.next_f64() * (n as f64 / 2.0 - 1.0);
        let kind = if rng.next_f64() < 0.5 { SphereKind::Centered } else { SphereKind::Wrapped };
        let spec = SphereSpec::new([n, n, n], radius, kind);
        let off = spec.offsets();

        // total == brute-force count
        let mut count = 0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    count += spec.contains(x, y, z) as usize;
                }
            }
        }
        assert_eq!(count, off.total(), "n={n} r={radius} {kind:?}");

        // x-restriction partitions the points for any p.
        let p = 1 + rng.next_below(n.min(6));
        let total: usize = (0..p).map(|r| off.restrict_x_cyclic(p, r).total()).sum();
        assert_eq!(total, off.total());

        // scatter/gather round trip with random batch.
        let nb = 1 + rng.next_below(4);
        let packed = rng.complex_vec(nb * off.total());
        let (dense, _) = off.scatter_z(&packed, nb);
        let back = off.gather_z(&dense, nb);
        assert_eq!(packed, back);
    }
}

#[test]
fn prop_distributed_fft_equals_local() {
    let mut rng = Prng::new(0xABCD);
    for case in 0..8 {
        let nx = 4 + 2 * rng.next_below(4);
        let ny = 3 + rng.next_below(6);
        let nz = 4 + 2 * rng.next_below(4);
        let nb = 1 + rng.next_below(3);
        let p = 1 + rng.next_below(nx.min(nz).min(4));
        let shape = [nx, ny, nz];
        let global = rng.complex_vec(nb * nx * ny * nz);

        let mut want = global.clone();
        let sh = [nb, nx, ny, nz];
        for dim in 1..4 {
            fftb::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
        }
        let global2 = global.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global2, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            plan.forward(&backend, local).0
        });
        let got = gather_cube_z(&outs, nb, shape, p);
        let err = max_abs_diff(&got, &want);
        assert!(
            err < 1e-7 * (nx * ny * nz) as f64,
            "case {case}: shape={shape:?} nb={nb} p={p} err={err}"
        );
    }
}

#[test]
fn prop_batched_transform_is_band_separable() {
    // Transforming a batch must equal transforming each band alone.
    let mut rng = Prng::new(0xCAFE);
    for _ in 0..5 {
        let n = 4 + 2 * rng.next_below(3);
        let nb = 2 + rng.next_below(3);
        let p = 1 + rng.next_below(2);
        let shape = [n, n, n];
        let global = rng.complex_vec(nb * n * n * n);
        let global2 = global.clone();
        let ok = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let single = SlabPencilPlan::new(shape, 1, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global2, nb, shape, p, grid.rank());
            let (all, _) = batched.forward(&backend, local.clone());
            let mut ok = true;
            for b in 0..nb {
                let band: Vec<Complex> =
                    local.iter().skip(b).step_by(nb).copied().collect();
                let (one, _) = single.forward(&backend, band);
                let band_from_batch: Vec<Complex> =
                    all.iter().skip(b).step_by(nb).copied().collect();
                ok &= max_abs_diff(&one, &band_from_batch) < 1e-10;
            }
            ok
        });
        assert!(ok.iter().all(|&b| b));
    }
}

#[test]
fn prop_comm_alltoall_permutation() {
    // Sending unique tokens: every token must arrive exactly once, at the
    // right destination.
    let mut rng = Prng::new(0xD00D);
    for _ in 0..10 {
        let p = 2 + rng.next_below(7);
        let outs = run_world(p, move |comm| {
            let me = comm.rank();
            let send: Vec<Vec<u8>> = (0..p)
                .map(|dst| vec![me as u8, dst as u8, (me * p + dst) as u8])
                .collect();
            fftb::comm::alltoallv(&comm, send)
        });
        for (dst, recv) in outs.iter().enumerate() {
            for (src, block) in recv.iter().enumerate() {
                assert_eq!(block, &vec![src as u8, dst as u8, (src * p + dst) as u8]);
            }
        }
    }
}

#[test]
fn prop_batching_driver_pipeline_depths_agree() {
    // The two-deep pipeline (de-interleave tail on the worker thread) must
    // be bit-identical to the synchronous driver for random batch sizes
    // and random forward/inverse flush orders — and both must be
    // allocation-free from the second flush on (one direction-agnostic
    // plan, warm workspace).
    let mut rng = Prng::new(0xD217);
    let shape = [8usize, 8, 8];
    let p = 2usize;
    for case in 0..6 {
        let nb = 1 + rng.next_below(3);
        let rounds = 3usize;
        // Per-round flush order: true = forward first, false = inverse
        // first. Drawn outside the worlds so every rank and both depths
        // see the same schedule.
        let order: Vec<bool> = (0..rounds).map(|_| rng.next_f64() < 0.5).collect();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let per_band = shape[0] * shape[1] * shape[2] / p;
            let mut run = |depth: usize| {
                let mut driver = BatchingDriver::new(shape, Arc::clone(&grid))
                    .with_pipeline_depth(depth);
                let mut got = Vec::new();
                let mut id = 0u64;
                for fwd_first in &order {
                    for dir in [Direction::Forward, Direction::Inverse] {
                        for _ in 0..nb {
                            driver.submit(TransformJob {
                                id,
                                data: phased(per_band, id),
                                dir,
                            });
                            id += 1;
                        }
                    }
                    let dirs = if *fwd_first {
                        [Direction::Forward, Direction::Inverse]
                    } else {
                        [Direction::Inverse, Direction::Forward]
                    };
                    for d in dirs {
                        assert_eq!(driver.flush(&backend, d), nb);
                    }
                }
                got.extend(driver.drain_completed());
                let traces = driver.drain_traces();
                assert_eq!(traces.len(), 2 * rounds);
                for (i, tr) in traces.iter().enumerate().skip(1) {
                    assert_eq!(
                        tr.alloc_bytes, 0,
                        "depth {depth} flush {i}: steady state must not allocate"
                    );
                }
                got
            };
            let d1 = run(1);
            let d2 = run(2);
            (d1, d2)
        });
        for (r, (d1, d2)) in outs.iter().enumerate() {
            assert_eq!(d1.len(), d2.len(), "case {case} rank {r}: result count");
            for ((id1, v1), (id2, v2)) in d1.iter().zip(d2) {
                assert_eq!(id1, id2, "case {case} rank {r}: order must match");
                assert_eq!(v1.len(), v2.len());
                for (a, b) in v1.iter().zip(v2) {
                    assert_eq!(a.re.to_bits(), b.re.to_bits(), "case {case} rank {r}");
                    assert_eq!(a.im.to_bits(), b.im.to_bits(), "case {case} rank {r}");
                }
            }
        }
    }
}

/// Split global packed real sphere coefficients into rank `r`'s packed
/// vector under the x-cyclic distribution (batch fastest) — the real
/// mirror of `scatter_cube_x` for sphere inputs.
fn scatter_sphere_real(
    off: &OffsetArray,
    packed: &[f64],
    nb: usize,
    p: usize,
    r: usize,
) -> Vec<f64> {
    let loc = off.restrict_x_cyclic(p, r);
    let mut out = Vec::with_capacity(nb * loc.total());
    for y in 0..off.ny {
        for lx in 0..loc.nx {
            let gx = cyclic::local_to_global(lx, p, r);
            let e0 = off.col_offset(gx, y);
            let n = off.col_len(gx, y);
            out.extend_from_slice(&packed[nb * e0..nb * (e0 + n)]);
        }
    }
    out
}

#[test]
fn prop_rfft_matches_naive_and_is_hermitian() {
    // The serial two-for-one r2c against the naive DFT of the embedded
    // real signal: the half spectrum matches bin for bin, the discarded
    // bins are exactly the conjugate mirror (Hermitian symmetry), and
    // c2r ∘ r2c is the identity — for random even lengths.
    let mut rng = Prng::new(0x2C2C);
    for case in 0..CASES {
        let n = 2 * (1 + rng.next_below(48)); // even, 2..96
        let x: Vec<f64> = (0..n).map(|_| rng.next_signed()).collect();
        let xc: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
        let want = naive_dft(&xc, Direction::Forward);
        let half = rfft(&x).unwrap();
        assert_eq!(half.len(), n / 2 + 1);
        for (k, h) in half.iter().enumerate() {
            let err = (*h - want[k]).abs();
            assert!(err < 1e-8 * n as f64, "case {case}: n={n} k={k} err={err}");
        }
        for k in 0..n {
            let err = (want[k] - want[(n - k) % n].conj()).abs();
            assert!(err < 1e-8 * n as f64, "case {case}: n={n} mirror k={k} err={err}");
        }
        let back = irfft(&half, n).unwrap();
        let err = x.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-9 * n as f64, "case {case}: n={n} round trip err={err}");
    }
}

#[test]
fn prop_distributed_r2c_gauntlet() {
    // The distributed r2c plane-wave plan over random spheres, batch
    // counts and world sizes. Five properties per case:
    //   1. forward == the c2c plan on every Hermitian-unique bin;
    //   2. the gathered output's self-conjugate planes (kz = 0 and the
    //      Nyquist plane) satisfy H[x,y,kz] = conj(H[-x,-y,kz]);
    //   3. linearity over real scalars: F(a x + y) = a F(x) + F(y);
    //   4. Parseval with plane weights (1 on the self-conjugate planes,
    //      2 elsewhere): sum w |H|^2 = n^3 * sum |x|^2;
    //   5. c2r ∘ r2c restores the packed real input.
    let mut rng = Prng::new(0x47C2);
    for case in 0..6 {
        let n = 6 + 2 * rng.next_below(6); // even, 6..16
        let h = n / 2;
        let nh = h + 1;
        let radius = 2.0 + rng.next_f64() * (n as f64 / 2.0 - 2.0);
        let kind = if rng.next_f64() < 0.5 { SphereKind::Centered } else { SphereKind::Wrapped };
        let spec = SphereSpec::new([n, n, n], radius, kind);
        let off = Arc::new(spec.offsets());
        let nb = 1 + rng.next_below(3);
        let p = 1 + rng.next_below(4.min(nh.min(n)));
        let xs: Vec<f64> = (0..nb * off.total()).map(|_| rng.next_signed()).collect();
        let ys: Vec<f64> = (0..nb * off.total()).map(|_| rng.next_signed()).collect();
        let a = rng.next_signed();

        let (off2, xs2, ys2) = (Arc::clone(&off), xs.clone(), ys.clone());
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let r = grid.rank();
            let lx = scatter_sphere_real(&off2, &xs2, nb, p, r);
            let ly = scatter_sphere_real(&off2, &ys2, nb, p, r);
            let rp = RealPlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();
            let cp = PlaneWavePlan::new(Arc::clone(&off2), nb, Arc::clone(&grid)).unwrap();

            let (hx, _) = rp.forward(&backend, lx.clone());
            let (hy, _) = rp.forward(&backend, ly.clone());
            let mixed: Vec<f64> = lx.iter().zip(&ly).map(|(x, y)| a * x + y).collect();
            let (hmix, _) = rp.forward(&backend, mixed);
            let lin_err = hmix
                .iter()
                .zip(hx.iter().zip(&hy))
                .map(|(m, (x, y))| (*m - (*x * a + *y)).abs())
                .fold(0.0f64, f64::max);

            let clocal: Vec<Complex> = lx.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let (ccube, _) = cp.forward(&backend, clocal);

            let (back, _) = rp.inverse(&backend, hx.clone());
            let rt_err =
                back.iter().zip(&lx).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
            (hx, ccube, lin_err, rt_err)
        });

        let scale = 1e-8 * (n * n * n) as f64;
        let hcubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.0.clone()).collect();
        let ccubes: Vec<Vec<Complex>> = outs.iter().map(|o| o.1.clone()).collect();
        let half = gather_cube_z(&hcubes, nb, [n, n, nh], p);
        let full = gather_cube_z(&ccubes, nb, [n, n, n], p);

        // 1. c2c agreement on the carried half.
        let err = half
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let e = i / nb;
                let b = i % nb;
                let (x, yz) = (e % n, e / n);
                let (y, kz) = (yz % n, yz / n);
                (*v - full[b + nb * (x + n * (y + n * kz))]).abs()
            })
            .fold(0.0f64, f64::max);
        assert!(err < 1e-11, "case {case}: n={n} nb={nb} p={p} vs c2c err={err}");

        // 2. Hermitian symmetry of the self-conjugate planes.
        for kz in [0, h] {
            for y in 0..n {
                for x in 0..n {
                    for b in 0..nb {
                        let v = half[b + nb * (x + n * (y + n * kz))];
                        let (mx, my) = ((n - x) % n, (n - y) % n);
                        let m = half[b + nb * (mx + n * (my + n * kz))];
                        let e = (v - m.conj()).abs();
                        assert!(e < scale, "case {case}: plane kz={kz} ({x},{y}) err={e}");
                    }
                }
            }
        }

        // 3. Linearity (checked per rank on local outputs).
        let lin = outs.iter().map(|o| o.2).fold(0.0f64, f64::max);
        assert!(lin < scale, "case {case}: linearity err={lin}");

        // 4. Parseval: the unnormalized forward of the zero-padded sphere,
        //    with mirror planes counted twice.
        let ex: f64 = xs.iter().map(|v| v * v).sum();
        let mut ef = 0.0f64;
        for kz in 0..nh {
            let w = if kz == 0 || kz == h { 1.0 } else { 2.0 };
            for e in 0..n * n {
                for b in 0..nb {
                    ef += w * half[b + nb * (e + n * n * kz)].norm_sqr();
                }
            }
        }
        let want = (n * n * n) as f64 * ex;
        assert!(
            (ef - want).abs() < 1e-8 * want.max(1.0),
            "case {case}: Parseval ef={ef} want={want}"
        );

        // 5. Round trip.
        let rt = outs.iter().map(|o| o.3).fold(0.0f64, f64::max);
        assert!(rt < 1e-11, "case {case}: round trip err={rt}");
    }
}

#[test]
fn prop_fft_shift_theorem() {
    // F(x shifted by s)[k] = F(x)[k] * w^{sk} — catches index/twiddle bugs
    // the round-trip test can't.
    let mut rng = Prng::new(0x51F7);
    for _ in 0..15 {
        let n = 4 + rng.next_below(60);
        let s = rng.next_below(n);
        let x = rng.complex_vec(n);
        let shifted: Vec<Complex> = (0..n).map(|i| x[(i + s) % n]).collect();
        let plan = Fft1d::new(n, Direction::Forward);
        let mut fx = x.clone();
        plan.run_batch_alloc(&mut fx);
        let mut fs = shifted;
        plan.run_batch_alloc(&mut fs);
        let mut want = vec![ZERO; n];
        for k in 0..n {
            let w = Complex::expi(-2.0 * std::f64::consts::PI * (s * k % n) as f64 / n as f64);
            // shift by +s in time = multiply by w^{+sk}? F(x[i+s])[k] =
            // F(x)[k] * e^{+2 pi i s k / n} with the e^{-2 pi i} kernel.
            want[k] = fx[k] * w.conj();
        }
        let err = max_abs_diff(&fs, &want);
        assert!(err < 1e-8 * n as f64, "n={n} s={s} err={err}");
    }
}
