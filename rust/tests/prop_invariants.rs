//! Property-based tests (hand-rolled harness: no proptest in the offline
//! dependency set — `fftb::util::prng` drives randomized cases with
//! deterministic seeds, so failures are reproducible by seed).
//!
//! Each property runs across a randomized family of sizes, rank counts,
//! batch sizes and sphere radii.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::coordinator::{BatchingDriver, TransformJob};
use fftb::fft::batch::Fft1d;
use fftb::fft::complex::{max_abs_diff, Complex, ZERO};
use fftb::fft::dft::{naive_dft, Direction};
use fftb::fftb::grid::{cyclic, ProcGrid};
use fftb::fftb::layout::Layout;
use fftb::fftb::plan::testutil::{gather_cube_z, phased, scatter_cube_x};
use fftb::fftb::plan::SlabPencilPlan;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::sphere::{SphereKind, SphereSpec};
use fftb::util::prng::Prng;

const CASES: usize = 25;

#[test]
fn prop_fft_matches_naive_dft_any_size() {
    let mut rng = Prng::new(0xF0F0);
    for case in 0..CASES {
        let n = 1 + rng.next_below(96);
        let x = rng.complex_vec(n);
        let dir = if rng.next_f64() < 0.5 { Direction::Forward } else { Direction::Inverse };
        let want = naive_dft(&x, dir);
        let plan = Fft1d::new(n, dir);
        let mut got = x.clone();
        plan.run_batch_alloc(&mut got);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-8 * n as f64, "case {case}: n={n} dir={dir:?} err={err}");
    }
}

#[test]
fn prop_fft_round_trip_and_linearity() {
    let mut rng = Prng::new(0xBEEF);
    for case in 0..CASES {
        let n = 2 + rng.next_below(64);
        let x = rng.complex_vec(n);
        let y = rng.complex_vec(n);
        let a = Complex::new(rng.next_signed(), rng.next_signed());
        let fwd = Fft1d::new(n, Direction::Forward);
        let inv = Fft1d::new(n, Direction::Inverse);

        // Round trip.
        let mut rt = x.clone();
        fwd.run_batch_alloc(&mut rt);
        inv.run_batch_alloc(&mut rt);
        assert!(max_abs_diff(&rt, &x) < 1e-9, "case {case}: round trip n={n}");

        // Linearity: F(a x + y) = a F(x) + F(y).
        let mut lhs: Vec<Complex> =
            x.iter().zip(&y).map(|(xv, yv)| a * *xv + *yv).collect();
        fwd.run_batch_alloc(&mut lhs);
        let mut fx = x.clone();
        fwd.run_batch_alloc(&mut fx);
        let mut fy = y.clone();
        fwd.run_batch_alloc(&mut fy);
        let rhs: Vec<Complex> = fx.iter().zip(&fy).map(|(xv, yv)| a * *xv + *yv).collect();
        assert!(max_abs_diff(&lhs, &rhs) < 1e-8 * n as f64, "case {case}: linearity n={n}");
    }
}

#[test]
fn prop_parseval() {
    let mut rng = Prng::new(0x1234);
    for _ in 0..CASES {
        let n = 1 + rng.next_below(128);
        let x = rng.complex_vec(n);
        let mut fx = x.clone();
        Fft1d::new(n, Direction::Forward).run_batch_alloc(&mut fx);
        let ex: f64 = x.iter().map(|v| v.norm_sqr()).sum();
        let ef: f64 = fx.iter().map(|v| v.norm_sqr()).sum::<f64>() / n as f64;
        assert!((ex - ef).abs() < 1e-8 * ex.max(1.0), "n={n}");
    }
}

#[test]
fn prop_cyclic_distribution_partition() {
    let mut rng = Prng::new(0x5555);
    for _ in 0..100 {
        let n = 1 + rng.next_below(500);
        let p = 1 + rng.next_below(16);
        let total: usize = (0..p).map(|r| cyclic::local_count(n, p, r)).sum();
        assert_eq!(total, n);
        let g = rng.next_below(n);
        let owner = cyclic::owner(g, p);
        let l = cyclic::global_to_local(g, p);
        assert_eq!(cyclic::local_to_global(l, p, owner), g);
        assert!(l < cyclic::local_count(n, p, owner));
    }
}

#[test]
fn prop_layout_parse_round_trip() {
    let mut rng = Prng::new(0x777);
    let names = ["x", "y", "z", "b", "w", "q1", "dim_a"];
    for _ in 0..50 {
        let ndim = 1 + rng.next_below(5);
        let mut used = Vec::new();
        let mut axes_used = Vec::new();
        let mut toks = Vec::new();
        for _ in 0..ndim {
            let name = loop {
                let c = *rng.choose(&names);
                if !used.contains(&c) {
                    break c;
                }
            };
            used.push(name);
            if rng.next_f64() < 0.4 {
                let axis = loop {
                    let a = rng.next_below(3);
                    if !axes_used.contains(&a) {
                        break a;
                    }
                };
                axes_used.push(axis);
                toks.push(format!("{name}{{{axis}}}"));
            } else {
                toks.push(name.to_string());
            }
        }
        let s = toks.join(" ");
        let l = Layout::parse(&s).expect("generated layouts must parse");
        assert_eq!(l.to_string_form(), s);
        assert_eq!(l.ndim(), ndim);
    }
}

#[test]
fn prop_sphere_offsets_consistent() {
    let mut rng = Prng::new(0x9999);
    for _ in 0..15 {
        let n = 6 + 2 * rng.next_below(8); // 6..20
        let radius = 1.0 + rng.next_f64() * (n as f64 / 2.0 - 1.0);
        let kind = if rng.next_f64() < 0.5 { SphereKind::Centered } else { SphereKind::Wrapped };
        let spec = SphereSpec::new([n, n, n], radius, kind);
        let off = spec.offsets();

        // total == brute-force count
        let mut count = 0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    count += spec.contains(x, y, z) as usize;
                }
            }
        }
        assert_eq!(count, off.total(), "n={n} r={radius} {kind:?}");

        // x-restriction partitions the points for any p.
        let p = 1 + rng.next_below(n.min(6));
        let total: usize = (0..p).map(|r| off.restrict_x_cyclic(p, r).total()).sum();
        assert_eq!(total, off.total());

        // scatter/gather round trip with random batch.
        let nb = 1 + rng.next_below(4);
        let packed = rng.complex_vec(nb * off.total());
        let (dense, _) = off.scatter_z(&packed, nb);
        let back = off.gather_z(&dense, nb);
        assert_eq!(packed, back);
    }
}

#[test]
fn prop_distributed_fft_equals_local() {
    let mut rng = Prng::new(0xABCD);
    for case in 0..8 {
        let nx = 4 + 2 * rng.next_below(4);
        let ny = 3 + rng.next_below(6);
        let nz = 4 + 2 * rng.next_below(4);
        let nb = 1 + rng.next_below(3);
        let p = 1 + rng.next_below(nx.min(nz).min(4));
        let shape = [nx, ny, nz];
        let global = rng.complex_vec(nb * nx * ny * nz);

        let mut want = global.clone();
        let sh = [nb, nx, ny, nz];
        for dim in 1..4 {
            fftb::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
        }
        let global2 = global.clone();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global2, nb, shape, p, grid.rank());
            let backend = RustFftBackend::new();
            plan.forward(&backend, local).0
        });
        let got = gather_cube_z(&outs, nb, shape, p);
        let err = max_abs_diff(&got, &want);
        assert!(
            err < 1e-7 * (nx * ny * nz) as f64,
            "case {case}: shape={shape:?} nb={nb} p={p} err={err}"
        );
    }
}

#[test]
fn prop_batched_transform_is_band_separable() {
    // Transforming a batch must equal transforming each band alone.
    let mut rng = Prng::new(0xCAFE);
    for _ in 0..5 {
        let n = 4 + 2 * rng.next_below(3);
        let nb = 2 + rng.next_below(3);
        let p = 1 + rng.next_below(2);
        let shape = [n, n, n];
        let global = rng.complex_vec(nb * n * n * n);
        let global2 = global.clone();
        let ok = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let batched = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            let single = SlabPencilPlan::new(shape, 1, Arc::clone(&grid)).unwrap();
            let local = scatter_cube_x(&global2, nb, shape, p, grid.rank());
            let (all, _) = batched.forward(&backend, local.clone());
            let mut ok = true;
            for b in 0..nb {
                let band: Vec<Complex> =
                    local.iter().skip(b).step_by(nb).copied().collect();
                let (one, _) = single.forward(&backend, band);
                let band_from_batch: Vec<Complex> =
                    all.iter().skip(b).step_by(nb).copied().collect();
                ok &= max_abs_diff(&one, &band_from_batch) < 1e-10;
            }
            ok
        });
        assert!(ok.iter().all(|&b| b));
    }
}

#[test]
fn prop_comm_alltoall_permutation() {
    // Sending unique tokens: every token must arrive exactly once, at the
    // right destination.
    let mut rng = Prng::new(0xD00D);
    for _ in 0..10 {
        let p = 2 + rng.next_below(7);
        let outs = run_world(p, move |comm| {
            let me = comm.rank();
            let send: Vec<Vec<u8>> = (0..p)
                .map(|dst| vec![me as u8, dst as u8, (me * p + dst) as u8])
                .collect();
            fftb::comm::alltoallv(&comm, send)
        });
        for (dst, recv) in outs.iter().enumerate() {
            for (src, block) in recv.iter().enumerate() {
                assert_eq!(block, &vec![src as u8, dst as u8, (src * p + dst) as u8]);
            }
        }
    }
}

#[test]
fn prop_batching_driver_pipeline_depths_agree() {
    // The two-deep pipeline (de-interleave tail on the worker thread) must
    // be bit-identical to the synchronous driver for random batch sizes
    // and random forward/inverse flush orders — and both must be
    // allocation-free from the second flush on (one direction-agnostic
    // plan, warm workspace).
    let mut rng = Prng::new(0xD217);
    let shape = [8usize, 8, 8];
    let p = 2usize;
    for case in 0..6 {
        let nb = 1 + rng.next_below(3);
        let rounds = 3usize;
        // Per-round flush order: true = forward first, false = inverse
        // first. Drawn outside the worlds so every rank and both depths
        // see the same schedule.
        let order: Vec<bool> = (0..rounds).map(|_| rng.next_f64() < 0.5).collect();
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm).unwrap();
            let backend = RustFftBackend::new();
            let per_band = shape[0] * shape[1] * shape[2] / p;
            let mut run = |depth: usize| {
                let mut driver = BatchingDriver::new(shape, Arc::clone(&grid))
                    .with_pipeline_depth(depth);
                let mut got = Vec::new();
                let mut id = 0u64;
                for fwd_first in &order {
                    for dir in [Direction::Forward, Direction::Inverse] {
                        for _ in 0..nb {
                            driver.submit(TransformJob {
                                id,
                                data: phased(per_band, id),
                                dir,
                            });
                            id += 1;
                        }
                    }
                    let dirs = if *fwd_first {
                        [Direction::Forward, Direction::Inverse]
                    } else {
                        [Direction::Inverse, Direction::Forward]
                    };
                    for d in dirs {
                        assert_eq!(driver.flush(&backend, d), nb);
                    }
                }
                got.extend(driver.drain_completed());
                let traces = driver.drain_traces();
                assert_eq!(traces.len(), 2 * rounds);
                for (i, tr) in traces.iter().enumerate().skip(1) {
                    assert_eq!(
                        tr.alloc_bytes, 0,
                        "depth {depth} flush {i}: steady state must not allocate"
                    );
                }
                got
            };
            let d1 = run(1);
            let d2 = run(2);
            (d1, d2)
        });
        for (r, (d1, d2)) in outs.iter().enumerate() {
            assert_eq!(d1.len(), d2.len(), "case {case} rank {r}: result count");
            for ((id1, v1), (id2, v2)) in d1.iter().zip(d2) {
                assert_eq!(id1, id2, "case {case} rank {r}: order must match");
                assert_eq!(v1.len(), v2.len());
                for (a, b) in v1.iter().zip(v2) {
                    assert_eq!(a.re.to_bits(), b.re.to_bits(), "case {case} rank {r}");
                    assert_eq!(a.im.to_bits(), b.im.to_bits(), "case {case} rank {r}");
                }
            }
        }
    }
}

#[test]
fn prop_fft_shift_theorem() {
    // F(x shifted by s)[k] = F(x)[k] * w^{sk} — catches index/twiddle bugs
    // the round-trip test can't.
    let mut rng = Prng::new(0x51F7);
    for _ in 0..15 {
        let n = 4 + rng.next_below(60);
        let s = rng.next_below(n);
        let x = rng.complex_vec(n);
        let shifted: Vec<Complex> = (0..n).map(|i| x[(i + s) % n]).collect();
        let plan = Fft1d::new(n, Direction::Forward);
        let mut fx = x.clone();
        plan.run_batch_alloc(&mut fx);
        let mut fs = shifted;
        plan.run_batch_alloc(&mut fs);
        let mut want = vec![ZERO; n];
        for k in 0..n {
            let w = Complex::expi(-2.0 * std::f64::consts::PI * (s * k % n) as f64 / n as f64);
            // shift by +s in time = multiply by w^{+sk}? F(x[i+s])[k] =
            // F(x)[k] * e^{+2 pi i s k / n} with the e^{-2 pi i} kernel.
            want[k] = fx[k] * w.conj();
        }
        let err = max_abs_diff(&fs, &want);
        assert!(err < 1e-8 * n as f64, "n={n} s={s} err={err}");
    }
}
