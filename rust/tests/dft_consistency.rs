//! Integration: the mini DFT application must give identical physics
//! regardless of how many ranks the transforms are distributed over —
//! the end-to-end guarantee that the distributed plane-wave pipeline
//! (scatter, staged pad, alltoall, truncate) is exact.

use std::sync::Arc;

use fftb::comm::communicator::run_world;
use fftb::dft::{build_density, solve_bands, EigenOptions, GaussianWells, Hamiltonian, Lattice};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::util::prng::Prng;

fn solve_with_ranks(p: usize) -> (Vec<f64>, f64) {
    let results = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
        let lat = Lattice::new(9.0, 12, 2.2);
        let nb = 3;
        let h = Hamiltonian::new(lat, nb, &GaussianWells::single(2.0, 1.4), grid);
        let backend = RustFftBackend::new();
        // Deterministic identical starting subspace on every rank count:
        // generate the GLOBAL bands and slice out this rank's rows so the
        // initial subspace is p-independent.
        let p_ranks = comm.size();
        let full_lat = Lattice::new(9.0, 12, 2.2);
        let all_kin_counts: Vec<usize> =
            (0..p_ranks).map(|r| full_lat.local_kinetic(p_ranks, r).len()).collect();
        let total: usize = all_kin_counts.iter().sum();
        let global = Prng::new(99).complex_vec(nb * total);
        // This rank's points start after the preceding ranks' in the
        // (rank-major) global enumeration we define here.
        let offset: usize = all_kin_counts[..comm.rank()].iter().sum();
        let mine = h.n_local();
        let mut psi = Vec::with_capacity(nb * mine);
        for e in 0..mine {
            for b in 0..nb {
                psi.push(global[b + nb * (offset + e)]);
            }
        }
        let res = solve_bands(
            &h,
            &backend,
            &comm,
            &mut psi,
            &EigenOptions { max_iters: 250, tol: 1e-7, ..Default::default() },
        );
        let d = build_density(&h, &backend, &comm, &psi);
        (res.eigenvalues, d.charge)
    });
    results.into_iter().next().unwrap()
}

#[test]
fn eigenvalues_independent_of_rank_count() {
    let (e1, c1) = solve_with_ranks(1);
    let (e2, c2) = solve_with_ranks(2);
    let (e4, c4) = solve_with_ranks(4);
    for b in 0..e1.len() {
        // Converged eigenvalues agree to solver tolerance regardless of the
        // distribution (different rank counts take different optimization
        // paths, so agreement is to tol, not machine epsilon).
        assert!(
            (e1[b] - e2[b]).abs() < 1e-5,
            "band {b}: p=1 {} vs p=2 {}",
            e1[b],
            e2[b]
        );
        assert!(
            (e1[b] - e4[b]).abs() < 1e-5,
            "band {b}: p=1 {} vs p=4 {}",
            e1[b],
            e4[b]
        );
    }
    assert!((c1 - 3.0).abs() < 1e-8);
    assert!((c2 - 3.0).abs() < 1e-8);
    assert!((c4 - 3.0).abs() < 1e-8);
}

#[test]
fn hamiltonian_apply_matches_across_rank_counts() {
    // H|psi> for the SAME global wavefunction must be identical whether
    // computed on 1 rank or 3 (exactness of the distributed transform pair,
    // no solver in the loop).
    let nb = 2;
    let gather = |p: usize| -> Vec<(f64, f64)> {
        let outs = run_world(p, move |comm| {
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let lat = Lattice::new(9.0, 12, 2.2);
            let h = Hamiltonian::new(lat, nb, &GaussianWells::dimer(1.5, 1.2, 0.3), grid);
            // Deterministic global coefficients: keyed by the kinetic
            // energy value of each point (a p-independent fingerprint).
            let kin = h.kinetic().to_vec();
            let mut psi = Vec::with_capacity(nb * kin.len());
            for &t in &kin {
                for b in 0..nb {
                    let s = (t * 13.7 + b as f64).sin();
                    psi.push(fftb::fft::complex::Complex::new(s, 0.5 * s));
                }
            }
            let backend = RustFftBackend::new();
            let (hpsi, _) = h.apply(&backend, &psi);
            // Return (kin fingerprint, value) pairs for comparison.
            kin.iter()
                .enumerate()
                .map(|(e, &t)| (t, hpsi[nb * e].re + 2.0 * hpsi[nb * e].im))
                .collect::<Vec<_>>()
        });
        let mut all: Vec<(f64, f64)> = outs.into_iter().flatten().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        all
    };
    let a = gather(1);
    let b = gather(3);
    assert_eq!(a.len(), b.len());
    for ((ta, va), (tb, vb)) in a.iter().zip(&b) {
        assert!((ta - tb).abs() < 1e-12);
        assert!((va - vb).abs() < 1e-8 * (1.0 + va.abs()), "{va} vs {vb}");
    }
}
