//! Schedule-perturbation tests: the comm layer's results must not depend
//! on *when* messages are delivered or waits complete, only on the
//! per-channel FIFO contract. `run_world_perturbed` arms every mailbox
//! with a seeded delivery policy (messages stage and release out of
//! post order across channels) and makes the fused exchange complete its
//! waits in a seeded pseudo-random round order — a zero-dep "loom-lite"
//! that explores interleavings a sanitizer would need a lucky thread
//! schedule to hit. Any correct SPMD program must return bit-identical
//! results under every seed; this file pins that for the flat windowed
//! exchange, the fused plan executions, and a full SCF iteration — each
//! with the exchange's helper worker thread disabled AND enabled
//! (`CommTuning::with_worker`), asserting the two modes agree with each
//! other and with the unperturbed world.

use std::sync::Arc;

use fftb::comm::alltoall::alltoallv_complex_flat_tuned;
use fftb::comm::{run_world, run_world_perturbed, CommTuning};
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner};
use fftb::fft::complex::{Complex, ZERO};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{Fftb, PlanKind, PlaneWavePlan, RealPlaneWavePlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};

/// Varied block extents with systematic empty blocks (extent 0 whenever
/// `3r + 5j ≡ 0 (mod 7)`) — the same pattern `tests/overlapped_exchange.rs`
/// uses, so empty wire messages ride through the perturbed schedules too.
fn block_len(r: usize, j: usize) -> usize {
    (r * 3 + 5 * j) % 7
}

/// One flat exchange on rank `me` of `p` with window `w` and the helper
/// worker on or off; deterministic content `f(src, dst, k)` so the result
/// is comparable across worlds and modes.
fn flat_exchange(comm: &fftb::comm::Comm, p: usize, w: usize, worker: bool) -> Vec<Complex> {
    let me = comm.rank();
    let mut send_offs = vec![0usize];
    let mut send: Vec<Complex> = Vec::new();
    for j in 0..p {
        for k in 0..block_len(me, j) {
            send.push(Complex::new((me * 31 + j) as f64, k as f64 + 0.25));
        }
        send_offs.push(send.len());
    }
    let mut recv_offs = vec![0usize];
    for q in 0..p {
        recv_offs.push(recv_offs[q] + block_len(q, me));
    }
    let mut out = vec![ZERO; *recv_offs.last().unwrap()];
    let _ = alltoallv_complex_flat_tuned(
        comm,
        &send,
        &send_offs,
        &mut out,
        &recv_offs,
        CommTuning::with_window(w).with_worker(worker),
    );
    out
}

/// Bitwise comparison of per-rank complex outputs (stricter than
/// `PartialEq`, which would let `-0.0 == 0.0` slip through).
fn assert_bits_eq(base: &[Vec<Complex>], got: &[Vec<Complex>], what: &str) {
    assert_eq!(base.len(), got.len(), "{what}: rank count differs");
    for (r, (a, b)) in base.iter().zip(got).enumerate() {
        assert_eq!(a.len(), b.len(), "{what}: rank {r} length differs");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                (x.re.to_bits(), x.im.to_bits()),
                (y.re.to_bits(), y.im.to_bits()),
                "{what}: rank {r} element {i} differs ({x:?} vs {y:?})"
            );
        }
    }
}

/// The flat windowed exchange (which runs on the fused engine) must be
/// bit-identical under every perturbation seed, for every window in
/// {1, 2, p-1} and worlds including a prime p — 16 seeds each, with the
/// helper worker thread both off and on.
#[test]
fn perturbed_flat_exchange_is_bit_identical() {
    for p in [2usize, 3, 5] {
        for w in [1usize, 2, p - 1] {
            let w = w.max(1);
            let base = run_world(p, move |comm| flat_exchange(&comm, p, w, false));
            let threaded = run_world(p, move |comm| flat_exchange(&comm, p, w, true));
            assert_bits_eq(&base, &threaded, &format!("p={p} w={w} worker-on unperturbed"));
            for seed in 0..16u64 {
                for worker in [false, true] {
                    let got = run_world_perturbed(p, seed, move |comm| {
                        flat_exchange(&comm, p, w, worker)
                    });
                    assert_bits_eq(
                        &base,
                        &got,
                        &format!("p={p} w={w} seed={seed} worker={worker}"),
                    );
                }
            }
        }
    }
}

/// Full fused plan executions (slab-pencil forward+inverse round trip)
/// under perturbed delivery and wait order: bit-identical to the
/// unperturbed world across seeds, including at prime p.
#[test]
fn perturbed_slab_pencil_is_bit_identical() {
    let shape = [6usize, 5, 6];
    let nb = 2usize;
    for p in [2usize, 3, 5] {
        let body = move |worker: bool| {
            move |comm: fftb::comm::Comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
                plan.set_tuning(CommTuning::with_window(2).with_worker(worker));
                let input = phased(plan.input_len(), grid.rank() as u64);
                let (spec, _) = plan.forward(&backend, input);
                let (back, _) = plan.inverse(&backend, spec.clone());
                spec.into_iter().chain(back).collect::<Vec<Complex>>()
            }
        };
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        assert_bits_eq(&base, &threaded, &format!("slab-pencil p={p} worker-on unperturbed"));
        for seed in 0..8u64 {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                assert_bits_eq(
                    &base,
                    &got,
                    &format!("slab-pencil p={p} seed={seed} worker={worker}"),
                );
            }
        }
    }
}

/// The plane-wave sphere plan (the SCF workhorse, with its uneven
/// per-rank block extents) under perturbation.
#[test]
fn perturbed_planewave_is_bit_identical() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let nb = 2usize;
    for p in [2usize, 3, 5] {
        let off = Arc::clone(&off);
        let body = move |worker: bool| {
            let off = Arc::clone(&off);
            move |comm: fftb::comm::Comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut plan =
                    PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
                plan.set_tuning(CommTuning::with_window(2).with_worker(worker));
                let input = phased(plan.input_len(), grid.rank() as u64);
                plan.forward(&backend, input).0
            }
        };
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        assert_bits_eq(&base, &threaded, &format!("plane-wave p={p} worker-on unperturbed"));
        for seed in 0..8u64 {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                assert_bits_eq(
                    &base,
                    &got,
                    &format!("plane-wave p={p} seed={seed} worker={worker}"),
                );
            }
        }
    }
}

/// The Hermitian half-spectrum (r2c/c2r) plan under perturbation: the
/// half-traffic exchange carries different per-rank block extents than the
/// c2c plan (nh = nz/2 + 1 z-planes, cyclically split), so it exercises
/// its own uneven wire pattern. Forward and the full round trip must be
/// bit-identical across seeds and worker modes.
#[test]
fn perturbed_r2c_round_trip_is_bit_identical() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let nb = 2usize;
    for p in [2usize, 3, 5] {
        let off = Arc::clone(&off);
        let body = move |worker: bool| {
            let off = Arc::clone(&off);
            move |comm: fftb::comm::Comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut plan =
                    RealPlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
                plan.set_tuning(CommTuning::with_window(2).with_worker(worker));
                let reals: Vec<f64> =
                    phased(plan.input_len(), grid.rank() as u64).iter().map(|c| c.re).collect();
                let (cube, _) = plan.forward(&backend, reals);
                let (back, _) = plan.inverse(&backend, cube.clone());
                cube.into_iter()
                    .chain(back.into_iter().map(|r| Complex::new(r, 0.0)))
                    .collect::<Vec<Complex>>()
            }
        };
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        assert_bits_eq(&base, &threaded, &format!("r2c p={p} worker-on unperturbed"));
        for seed in 0..8u64 {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                assert_bits_eq(&base, &got, &format!("r2c p={p} seed={seed} worker={worker}"));
            }
        }
    }
}

/// A k-point-offset sphere (k = [0.25, 0, 0]) through the c2c plane-wave
/// plan under perturbation: the shifted sphere's asymmetric z-runs produce
/// per-rank extents no Γ-point test covers. Bit-identical across seeds
/// and worker modes.
#[test]
fn perturbed_offset_sphere_planewave_is_bit_identical() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offset([0.25, 0.0, 0.0]));
    assert_ne!(
        off.fingerprint(),
        spec.offsets().fingerprint(),
        "the offset sphere must be a distinct workload"
    );
    let nb = 2usize;
    for p in [2usize, 3, 5] {
        let off = Arc::clone(&off);
        let body = move |worker: bool| {
            let off = Arc::clone(&off);
            move |comm: fftb::comm::Comm| {
                let grid = ProcGrid::new(&[p], comm).unwrap();
                let backend = RustFftBackend::new();
                let mut plan =
                    PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
                plan.set_tuning(CommTuning::with_window(2).with_worker(worker));
                let input = phased(plan.input_len(), grid.rank() as u64);
                let (spec_out, _) = plan.forward(&backend, input);
                let (back, _) = plan.inverse(&backend, spec_out.clone());
                spec_out.into_iter().chain(back).collect::<Vec<Complex>>()
            }
        };
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        assert_bits_eq(&base, &threaded, &format!("offset-sphere p={p} worker-on unperturbed"));
        for seed in 0..8u64 {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                assert_bits_eq(
                    &base,
                    &got,
                    &format!("offset-sphere p={p} seed={seed} worker={worker}"),
                );
            }
        }
    }
}

/// A full tuner-driven SCF iteration — orthonormalization, batched
/// sphere transforms, subspace reductions, density mixing — must produce
/// bit-identical scalars and densities under perturbed schedules. This is
/// the steady-state contract end to end: fixed-order reductions plus
/// destination-disjoint exchanges leave no room for delivery order to
/// leak into results.
#[test]
fn perturbed_scf_is_bit_identical() {
    const N: usize = 12;
    const A: f64 = 8.0;
    const ECUT: f64 = 2.0;
    const NB: usize = 2;
    let body = move |comm: fftb::comm::Comm| {
        let lat = Lattice::new(A, N, ECUT);
        let backend = RustFftBackend::new();
        let opts = ScfOptions { max_iters: 2, tol: 0.0, coupling: 0.3, ..Default::default() };
        let mut runner = ScfRunner::new(lat, NB, &GaussianWells::single(2.0, 1.4), &comm,
            &backend, opts)
            .expect("plan_auto_scf must find a feasible plan");
        let res = runner.run(&backend);
        let mut scalars: Vec<f64> = res.eigenvalues.clone();
        for s in &res.history {
            scalars.push(s.charge);
            scalars.push(s.delta_rho);
            scalars.push(s.max_residual);
            scalars.push(s.energy.total);
            scalars.push(s.energy.hartree);
        }
        (scalars, res.density.rho)
    };
    for p in [2usize, 3, 5] {
        let base = run_world(p, body);
        for seed in [1u64, 7, 23, 99, 1234, 0xDEAD_BEEF] {
            let got = run_world_perturbed(p, seed, body);
            assert_eq!(base.len(), got.len());
            for (r, ((bs, brho), (gs, grho))) in base.iter().zip(&got).enumerate() {
                for (i, (a, b)) in bs.iter().zip(gs).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "p={p} seed={seed} rank {r}: scalar {i} differs ({a} vs {b})"
                    );
                }
                for (i, (a, b)) in brho.iter().zip(grho).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "p={p} seed={seed} rank {r}: rho[{i}] differs ({a} vs {b})"
                    );
                }
            }
        }
    }
}

/// The same 2-iteration SCF cadence through a pinned plane-wave plan
/// whose exchanges run on the threaded engine: worker-on must be
/// bit-identical to worker-off, unperturbed and under perturbed
/// schedules alike. (The tuner-driven test above owns its own worker
/// choice; pinning the plan is what lets this one force the axis.)
#[test]
fn perturbed_scf_with_worker_is_bit_identical() {
    const N: usize = 12;
    const A: f64 = 8.0;
    const ECUT: f64 = 2.0;
    const NB: usize = 2;
    let body = move |worker: bool| {
        move |comm: fftb::comm::Comm| {
            let lat = Lattice::new(A, N, ECUT);
            let backend = RustFftBackend::new();
            let grid = ProcGrid::new(&[comm.size()], comm.clone()).unwrap();
            let plan = PlaneWavePlan::new(Arc::clone(&lat.offsets), NB, grid).unwrap();
            let mut fx = Fftb { kind: PlanKind::PlaneWave(plan), sizes: [N, N, N], nb: NB };
            fx.set_comm_tuning(CommTuning::with_window(2).with_worker(worker));
            let opts =
                ScfOptions { max_iters: 2, tol: 0.0, coupling: 0.3, ..Default::default() };
            let mut runner = ScfRunner::with_plan(
                lat,
                NB,
                &GaussianWells::single(2.0, 1.4),
                &comm,
                Arc::new(fx),
                opts,
            )
            .expect("the pinned plane-wave plan must assemble");
            let res = runner.run(&backend);
            let mut scalars: Vec<f64> = res.eigenvalues.clone();
            for s in &res.history {
                scalars.push(s.charge);
                scalars.push(s.delta_rho);
                scalars.push(s.max_residual);
                scalars.push(s.energy.total);
                scalars.push(s.energy.hartree);
            }
            (scalars, res.density.rho)
        }
    };
    let check = |base: &[(Vec<f64>, Vec<f64>)], got: &[(Vec<f64>, Vec<f64>)], what: &str| {
        assert_eq!(base.len(), got.len(), "{what}: rank count");
        for (r, ((bs, brho), (gs, grho))) in base.iter().zip(got).enumerate() {
            for (i, (a, b)) in bs.iter().zip(gs).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{what} rank {r}: scalar {i} differs");
            }
            for (i, (a, b)) in brho.iter().zip(grho).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{what} rank {r}: rho[{i}] differs");
            }
        }
    };
    for p in [2usize, 3, 5] {
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        check(&base, &threaded, &format!("scf p={p} worker-on unperturbed"));
        for seed in [1u64, 23, 0xDEAD_BEEF] {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                check(&base, &got, &format!("scf p={p} seed={seed} worker={worker}"));
            }
        }
    }
}
