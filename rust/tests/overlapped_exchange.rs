//! The overlapped (windowed) exchange must be a pure *scheduling* change:
//! bit-identical results to the serial schedule for every window size
//! ({1, 2, p-1}), world size (including non-powers of two), and block
//! pattern (including empty remote blocks) — with correctly reported
//! overlap counters, and identical plan outputs when threaded through the
//! five plan kinds via `set_tuning` / `FftbOptions::comm`.

use std::sync::Arc;

use fftb::comm::alltoall::{alltoallv_complex_flat_serial, alltoallv_complex_flat_tuned};
use fftb::comm::{run_world, CommTuning};
use fftb::fft::complex::{Complex, ZERO};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{NonBatchedLoop, PencilPlan, PlaneWavePlan, SlabPencilPlan};
use fftb::fftb::sphere::{SphereKind, SphereSpec};

/// Varied block extents with systematic empty blocks (both self and
/// remote: extent 0 whenever `3r + 5j ≡ 0 (mod 7)`).
fn block_len(r: usize, j: usize) -> usize {
    (r * 3 + 5 * j) % 7
}

#[test]
fn windowed_pipeline_is_bit_identical_to_serial() {
    for p in [2usize, 3, 5, 6] {
        let outs = run_world(p, move |comm| {
            let me = comm.rank();
            let mut send_offs = vec![0usize];
            let mut send: Vec<Complex> = Vec::new();
            for j in 0..p {
                for k in 0..block_len(me, j) {
                    send.push(Complex::new((me * 31 + j) as f64, k as f64 + 0.25));
                }
                send_offs.push(send.len());
            }
            let mut recv_offs = vec![0usize];
            for q in 0..p {
                recv_offs.push(recv_offs[q] + block_len(q, me));
            }
            let n = *recv_offs.last().unwrap();

            let mut base = vec![ZERO; n];
            let c0 =
                alltoallv_complex_flat_serial(&comm, &send, &send_offs, &mut base, &recv_offs);
            assert_eq!(c0.overlap_rounds, 0, "serial schedule never overlaps");

            let mut results = Vec::new();
            for w in [1usize, 2, p - 1] {
                let mut out = vec![ZERO; n];
                let c = alltoallv_complex_flat_tuned(
                    &comm,
                    &send,
                    &send_offs,
                    &mut out,
                    &recv_offs,
                    CommTuning::with_window(w.max(1)),
                );
                if w <= 1 || p == 2 {
                    // Window 1 (or a 2-rank world, where any window clamps
                    // to 1) keeps the serial ordering.
                    assert_eq!(c.overlap_rounds, 0, "window {w} must not overlap at p={p}");
                } else {
                    // The pipeline stays full: every round but the first
                    // is posted ahead of the serial schedule.
                    assert_eq!(c.overlap_rounds as usize, p - 2, "window {w} at p={p}");
                }
                results.push(out);
            }
            (base, results)
        });
        for (base, results) in outs {
            for got in results {
                assert_eq!(base, got, "p={p}: windowed result differs from serial");
            }
        }
    }
}

/// The plans' outputs must be bitwise invariant under the exchange window
/// (the window changes when blocks move, never where they land), and the
/// overlapped executions must report their counters.
#[test]
fn slab_pencil_outputs_invariant_under_window() {
    let shape = [6usize, 5, 6]; // non-pow2, uneven cyclic counts
    let (nb, p) = (2usize, 3usize);
    run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let run_with = |w: usize| {
            let mut plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input)
        };
        let (base, tr1) = run_with(1);
        assert_eq!(tr1.overlap_rounds, 0);
        let (o2, tr2) = run_with(2);
        assert!(tr2.overlap_rounds > 0, "windowed plan must overlap rounds");
        let (of, _) = run_with(p - 1);
        assert_eq!(base, o2, "window 2 output differs");
        assert_eq!(base, of, "full-window output differs");
    });
}

#[test]
fn pencil_outputs_invariant_under_window() {
    let shape = [8usize, 8, 8];
    let nb = 1usize;
    let (p0, p1) = (2usize, 3usize);
    run_world(p0 * p1, move |comm| {
        let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
        let backend = RustFftBackend::new();
        let run_with = |w: usize| {
            let mut plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let base = run_with(1);
        assert_eq!(base, run_with(2), "window 2 output differs");
        assert_eq!(base, run_with(4), "window 4 output differs");
    });
}

#[test]
fn planewave_and_loop_outputs_invariant_under_window() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 4usize);
    run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();

        let pw_with = |w: usize| {
            let mut plan = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let base = pw_with(1);
        assert_eq!(base, pw_with(p - 1), "plane-wave output differs across windows");

        let loop_with = |w: usize| {
            let mut plan = NonBatchedLoop::new([8, 8, 8], nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let lbase = loop_with(1);
        assert_eq!(lbase, loop_with(p - 1), "loop output differs across windows");
    });
}
