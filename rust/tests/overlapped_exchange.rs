//! The overlapped (windowed) exchange must be a pure *scheduling* change:
//! bit-identical results to the serial schedule for every window size
//! ({1, 2, p-1}), world size (including non-powers of two and primes), and
//! block pattern (including empty remote blocks) — with correctly reported
//! overlap counters, and identical plan outputs when threaded through the
//! five plan kinds via `set_tuning` / `FftbOptions::comm`.
//!
//! The same holds for the **fused** engine: driving per-destination
//! `PackKernel`s through the windowed pipeline (pack into the wire buffer
//! as each round posts, unpack as each wait completes) must be
//! bit-identical to the monolithic pre-pack → flat exchange → merge path
//! it replaced, for every window, and must report nonzero
//! `pack_overlap_ns` / `unpack_overlap_ns` once there is more than one
//! remote round.

use std::sync::Arc;

use fftb::comm::alltoall::{alltoallv_complex_flat_serial, alltoallv_complex_flat_tuned};
use fftb::comm::{run_world, CommTuning};
use fftb::fft::complex::{Complex, ZERO};
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::{cyclic, ProcGrid};
use fftb::fftb::plan::redistribute::{merge_dim_from, split_dim_into, volume};
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{
    fused_exchange, A2aSchedule, NonBatchedLoop, PencilPlan, PlaneWavePlan, SlabPencilPlan,
    SplitMergeKernel,
};
use fftb::fftb::sphere::{SphereKind, SphereSpec};

/// Varied block extents with systematic empty blocks (both self and
/// remote: extent 0 whenever `3r + 5j ≡ 0 (mod 7)`).
fn block_len(r: usize, j: usize) -> usize {
    (r * 3 + 5 * j) % 7
}

#[test]
fn windowed_pipeline_is_bit_identical_to_serial() {
    for p in [2usize, 3, 5, 6] {
        let outs = run_world(p, move |comm| {
            let me = comm.rank();
            let mut send_offs = vec![0usize];
            let mut send: Vec<Complex> = Vec::new();
            for j in 0..p {
                for k in 0..block_len(me, j) {
                    send.push(Complex::new((me * 31 + j) as f64, k as f64 + 0.25));
                }
                send_offs.push(send.len());
            }
            let mut recv_offs = vec![0usize];
            for q in 0..p {
                recv_offs.push(recv_offs[q] + block_len(q, me));
            }
            let n = *recv_offs.last().unwrap();

            let mut base = vec![ZERO; n];
            let c0 =
                alltoallv_complex_flat_serial(&comm, &send, &send_offs, &mut base, &recv_offs);
            assert_eq!(c0.overlap_rounds, 0, "serial schedule never overlaps");

            let mut results = Vec::new();
            for w in [1usize, 2, p - 1] {
                let mut out = vec![ZERO; n];
                let c = alltoallv_complex_flat_tuned(
                    &comm,
                    &send,
                    &send_offs,
                    &mut out,
                    &recv_offs,
                    CommTuning::with_window(w.max(1)),
                );
                if w <= 1 || p == 2 {
                    // Window 1 (or a 2-rank world, where any window clamps
                    // to 1) keeps the serial ordering.
                    assert_eq!(c.overlap_rounds, 0, "window {w} must not overlap at p={p}");
                } else {
                    // The pipeline stays full: every round but the first
                    // is posted ahead of the serial schedule.
                    assert_eq!(c.overlap_rounds as usize, p - 2, "window {w} at p={p}");
                }
                results.push(out);
            }
            (base, results)
        });
        for (base, results) in outs {
            for got in results {
                assert_eq!(base, got, "p={p}: windowed result differs from serial");
            }
        }
    }
}

/// The fused engine (per-destination kernels packing into wire buffers
/// round by round, unpacking as waits complete) must be bit-identical to
/// the monolithic path it replaced — pre-pack with `split_dim_into`, flat
/// windowed exchange, `merge_dim_from` — on the slab exchange geometry
/// (split z of the x-distributed tensor, merge x of the z-distributed
/// one), for every window in {1, 2, p-1} and worlds including a prime p
/// with uneven cyclic extents.
#[test]
fn fused_kernel_exchange_matches_prepacked_path() {
    let (nx, ny, nz, nb) = (5usize, 3usize, 7usize, 2usize);
    for p in [2usize, 3, 5] {
        let ok = run_world(p, move |comm| {
            let me = comm.rank();
            let lxc = cyclic::local_count(nx, p, me);
            let lzc = cyclic::local_count(nz, p, me);
            let sh_in = [nb, lxc, ny, nz];
            let sh_out = [nb, nx, ny, lzc];
            let sched = A2aSchedule::for_split_merge(sh_in, 3, sh_out, 1, p, me);
            let data = phased(volume(sh_in), 100 + me as u64);

            // Reference: monolithic pre-pack -> flat exchange -> merge.
            let mut send = vec![ZERO; sched.send_total()];
            split_dim_into(&data, sh_in, 3, p, &mut send, &sched.send_offs);
            let mut recv = vec![ZERO; sched.recv_total()];
            let _ = alltoallv_complex_flat_tuned(
                &comm,
                &send,
                &sched.send_offs,
                &mut recv,
                &sched.recv_offs,
                CommTuning::serial(),
            );
            let mut want = vec![ZERO; volume(sh_out)];
            merge_dim_from(&recv, &sched.recv_offs, sh_out, 1, p, &mut want);

            // Fused: pack kernels driven by the windowed engine. Overlap
            // nanoseconds are summed across the windows (individual packs
            // here are sub-microsecond; the sum keeps the assertion off
            // the mercy of clock granularity).
            let mut ok = true;
            let (mut pack_ns, mut unpack_ns) = (0u64, 0u64);
            for w in [1usize, 2, p - 1] {
                let mut got = vec![ZERO; volume(sh_out)];
                let c = {
                    let mut k =
                        SplitMergeKernel::new(&sched, &data, sh_in, 3, &mut got, sh_out, 1);
                    fused_exchange(&comm, &mut k, CommTuning::with_window(w.max(1)))
                };
                ok &= got == want;
                pack_ns += c.pack_overlap_ns;
                unpack_ns += c.unpack_overlap_ns;
            }
            if p > 2 {
                // More than one remote round: packing rounds >= 2 and
                // unpacking all but the last round overlap the exchange,
                // and the engine must account for it.
                assert!(pack_ns > 0, "p={p}: no fused pack recorded");
                assert!(unpack_ns > 0, "p={p}: no fused unpack recorded");
            }
            ok
        });
        assert!(ok.iter().all(|&b| b), "p={p}: fused exchange differs from pre-packed path");
    }
}

/// The plans' outputs must be bitwise invariant under the exchange window
/// (the window changes when blocks move, never where they land), and the
/// overlapped executions must report their counters.
#[test]
fn slab_pencil_outputs_invariant_under_window() {
    let shape = [6usize, 5, 6]; // non-pow2, uneven cyclic counts
    let (nb, p) = (2usize, 3usize);
    run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let run_with = |w: usize| {
            let mut plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input)
        };
        let (base, tr1) = run_with(1);
        assert_eq!(tr1.overlap_rounds, 0);
        let (o2, tr2) = run_with(2);
        assert!(tr2.overlap_rounds > 0, "windowed plan must overlap rounds");
        let (of, _) = run_with(p - 1);
        assert_eq!(base, o2, "window 2 output differs");
        assert_eq!(base, of, "full-window output differs");
    });
}

#[test]
fn pencil_outputs_invariant_under_window() {
    let shape = [8usize, 8, 8];
    let nb = 1usize;
    let (p0, p1) = (2usize, 3usize);
    run_world(p0 * p1, move |comm| {
        let grid = ProcGrid::new(&[p0, p1], comm).unwrap();
        let backend = RustFftBackend::new();
        let run_with = |w: usize| {
            let mut plan = PencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let base = run_with(1);
        assert_eq!(base, run_with(2), "window 2 output differs");
        assert_eq!(base, run_with(4), "window 4 output differs");
    });
}

#[test]
fn planewave_and_loop_outputs_invariant_under_window() {
    let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
    let off = Arc::new(spec.offsets());
    let (nb, p) = (2usize, 4usize);
    run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();

        let pw_with = |w: usize| {
            let mut plan = PlaneWavePlan::new(Arc::clone(&off), nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let base = pw_with(1);
        assert_eq!(base, pw_with(p - 1), "plane-wave output differs across windows");

        let loop_with = |w: usize| {
            let mut plan = NonBatchedLoop::new([8, 8, 8], nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let lbase = loop_with(1);
        assert_eq!(lbase, loop_with(p - 1), "loop output differs across windows");
    });
}

/// Prime-p communicator: the pairwise round schedule has no power-of-two
/// structure to hide behind, and every cyclic extent is uneven. The fused
/// plan outputs must still be bitwise invariant under the window.
#[test]
fn slab_pencil_prime_p_invariant_under_window() {
    let shape = [5usize, 4, 10];
    let (nb, p) = (2usize, 5usize);
    run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let run_with = |w: usize| {
            let mut plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
            plan.set_tuning(CommTuning::with_window(w));
            let input = phased(plan.input_len(), grid.rank() as u64);
            plan.forward(&backend, input).0
        };
        let base = run_with(1);
        assert_eq!(base, run_with(2), "window 2 output differs at prime p");
        assert_eq!(base, run_with(p - 1), "full-window output differs at prime p");
    });
}

/// Compute/comm fusion must actually overlap: when one rank's compute is
/// artificially delayed (the skewed-rank regime the windowed pipeline
/// exists for), every rank's trace must report pack work done while the
/// exchange was in flight (`pack_overlap_ns`) and unpack work done before
/// the final round completed (`unpack_overlap_ns`).
#[test]
fn skewed_rank_fusion_overlaps_pack_and_unpack() {
    // 32x16x32 with nb=2: each per-destination block is ~32 KiB, so every
    // timed pack/unpack is tens of microseconds — far above any realistic
    // clock granularity (no flaky zero readings).
    let shape = [32usize, 16, 32];
    let (nb, p) = (2usize, 4usize);
    let traces = run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let backend = RustFftBackend::new();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let input = phased(plan.input_len(), grid.rank() as u64);
        if grid.rank() == 0 {
            // One laggard: its partners reach the exchange first and sit
            // in waits — exactly where fused packing buys time back.
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        plan.forward(&backend, input).1
    });
    for (r, tr) in traces.iter().enumerate() {
        assert!(
            tr.pack_overlap_ns > 0,
            "rank {r}: packing must overlap the in-flight exchange (got 0 ns)"
        );
        assert!(
            tr.unpack_overlap_ns > 0,
            "rank {r}: unpacking must overlap outstanding rounds (got 0 ns)"
        );
    }
}
