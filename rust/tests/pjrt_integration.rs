//! Integration: the PJRT artifact path (python AOT -> HLO text -> rust
//! PJRT execute) against the pure-rust substrate, standalone and inside the
//! distributed plans.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use std::sync::Arc;

use fftb::fft::complex::{rel_l2_err, Complex};
use fftb::fft::dft::Direction;
use fftb::fftb::backend::{LocalFftBackend, RustFftBackend};
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::{gather_cube_z, phased, scatter_cube_x};
use fftb::fftb::plan::SlabPencilPlan;
use fftb::runtime::{PjrtFftBackend, PjrtRuntime};

fn runtime() -> Option<Arc<PjrtRuntime>> {
    if !cfg!(feature = "pjrt") {
        eprintln!(
            "skipping PJRT integration tests: built without the `pjrt` feature \
             (add the vendored `xla` crate to rust/Cargo.toml, then rebuild \
             with `cargo test --features pjrt` — see rust/README.md)"
        );
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping PJRT integration tests: run `make artifacts` first");
        return None;
    }
    Some(Arc::new(PjrtRuntime::open("artifacts").expect("open artifacts")))
}

#[test]
fn manifest_lists_fft_sizes() {
    let Some(rt) = runtime() else { return };
    let sizes = rt.manifest().fft_sizes();
    assert!(sizes.contains(&16), "sizes = {sizes:?}");
    assert!(sizes.contains(&64));
    assert!(sizes.contains(&256));
}

#[test]
fn pjrt_backend_matches_rust_backend() {
    let Some(rt) = runtime() else { return };
    let pjrt = PjrtFftBackend::new(rt);
    let rust = RustFftBackend::new();
    for n in [16usize, 64, 128] {
        for dir in [Direction::Forward, Direction::Inverse] {
            // 3 full artifact tiles + a ragged tail.
            let nlines = 3 * 64 + 17;
            let mut a = phased(nlines * n, n as u64);
            let mut b = a.clone();
            pjrt.fft_batch(&mut a, n, dir);
            rust.fft_batch(&mut b, n, dir);
            let err = rel_l2_err(&a, &b);
            assert!(err < 5e-4, "n={n} dir={dir:?} rel err {err}");
        }
    }
}

#[test]
fn pjrt_backend_falls_back_for_unknown_sizes() {
    let Some(rt) = runtime() else { return };
    let pjrt = PjrtFftBackend::new(rt);
    let rust = RustFftBackend::new();
    let n = 12; // no artifact for non-pow2
    let mut a = phased(5 * n, 3);
    let mut b = a.clone();
    pjrt.fft_batch(&mut a, n, Direction::Forward);
    rust.fft_batch(&mut b, n, Direction::Forward);
    assert!(rel_l2_err(&a, &b) < 1e-12, "fallback should be bit-identical");
    assert!(pjrt.fallback_lines.load(std::sync::atomic::Ordering::Relaxed) > 0);
    assert_eq!(pjrt.pjrt_lines.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn distributed_plan_runs_on_pjrt_backend() {
    let Some(rt) = runtime() else { return };
    let shape = [16usize, 16, 16];
    let nb = 2;
    let p = 2;
    let global: Vec<Complex> = phased(nb * 16 * 16 * 16, 11);

    // Oracle through the rust backend.
    let mut want = global.clone();
    let sh = [nb, 16, 16, 16];
    for dim in 1..4 {
        fftb::fft::nd::fft_dim(&mut want, &sh, dim, Direction::Forward);
    }

    let backend = Arc::new(PjrtFftBackend::new(rt));
    let backend2 = Arc::clone(&backend);
    let global2 = global.clone();
    let outs = fftb::comm::run_world(p, move |comm| {
        let grid = ProcGrid::new(&[p], comm).unwrap();
        let plan = SlabPencilPlan::new(shape, nb, Arc::clone(&grid)).unwrap();
        let local = scatter_cube_x(&global2, nb, shape, p, grid.rank());
        let (out, _) = plan.forward(backend2.as_ref(), local);
        out
    });
    let got = gather_cube_z(&outs, nb, shape, p);
    let err = rel_l2_err(&got, &want);
    assert!(err < 5e-4, "distributed PJRT vs rust oracle: rel err {err}");
    assert!(backend.pjrt_lines.load(std::sync::atomic::Ordering::Relaxed) > 0);
}

#[test]
fn pad_fft_artifact_matches_substrate() {
    // The fused pad+FFT artifact (Fig. 3 insight as an MXU matmul):
    // padfft_8_16_4_f pads an 8-run at offset 4 into a 16-line and DFTs it.
    let Some(rt) = runtime() else { return };
    let (m, n, o) = (8usize, 16usize, 4usize);
    let batch = rt.manifest().batch;
    let lines = phased(batch * m, 5);
    let mut input = Vec::with_capacity(batch * m * 2);
    for c in &lines {
        input.push(c.re as f32);
        input.push(c.im as f32);
    }
    let out = rt.execute_f32(&format!("padfft_{m}_{n}_{o}_f"), &input).unwrap();
    assert_eq!(out.len(), batch * n * 2);

    // Oracle: scatter into padded lines, rust FFT.
    let rust = RustFftBackend::new();
    let mut padded = vec![fftb::fft::complex::ZERO; batch * n];
    for l in 0..batch {
        for k in 0..m {
            padded[l * n + o + k] = lines[l * m + k];
        }
    }
    rust.fft_batch(&mut padded, n, Direction::Forward);
    let got: Vec<Complex> = out
        .chunks_exact(2)
        .map(|p| Complex::new(p[0] as f64, p[1] as f64))
        .collect();
    let err = rel_l2_err(&got, &padded);
    assert!(err < 5e-4, "pad+FFT artifact rel err {err}");
}
