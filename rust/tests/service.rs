//! Multi-tenant transform service integration tests: coalesced batches
//! must be bit-identical to sequential per-tenant execution across world
//! sizes, quotas and the backlog window must reject with typed errors and
//! leak nothing, steady-state flushes must be allocation-free, the
//! service-driven SCF loop must match standalone runs bit-for-bit while
//! provably coalescing exchanges, and the whole submit/flush path must
//! survive the schedule-perturbation gauntlet.

use std::sync::Arc;

use fftb::comm::{run_world, run_world_perturbed, Comm, CommTuning};
use fftb::dft::{GaussianWells, Lattice, ScfOptions, ScfRunner, ScfServiceDriver};
use fftb::fft::complex::Complex;
use fftb::fft::Direction;
use fftb::fftb::backend::RustFftBackend;
use fftb::fftb::grid::ProcGrid;
use fftb::fftb::plan::testutil::phased;
use fftb::fftb::plan::{Fftb, PlanKind, PlaneWavePlan};
use fftb::fftb::sphere::{OffsetArray, SphereKind, SphereSpec};
use fftb::service::{ServiceConfig, ServiceError, TransformService};

fn sphere() -> Arc<OffsetArray> {
    Arc::new(SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped).offsets())
}

fn service_on(p: usize, comm: &Comm, tuning: CommTuning) -> TransformService {
    let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
    let config = ServiceConfig { tuning, ..Default::default() };
    TransformService::new([8, 8, 8], grid, config).unwrap()
}

fn assert_slots_bits_eq(a: &[Complex], b: &[Complex], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length differs");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            (x.re.to_bits(), x.im.to_bits()),
            (y.re.to_bits(), y.im.to_bits()),
            "{what}: element {i} differs ({x:?} vs {y:?})"
        );
    }
}

/// Two tenants' bands coalesced into ONE flush must be bit-identical, on
/// every rank, to the same requests flushed sequentially per tenant —
/// and to the single-band plane-wave plan run band by band. This is the
/// service's core correctness claim: coalescing changes the batching,
/// never the numbers.
#[test]
fn coalesced_flush_is_bit_identical_to_sequential_per_tenant_runs() {
    for p in [1usize, 2, 4] {
        let off = sphere();
        let ok = run_world(p, move |comm| {
            let backend = RustFftBackend::new();

            // Coalesced: a and b interleave five bands, one flush.
            let mut svc = service_on(p, &comm, CommTuning::default());
            let a = svc.register_tenant("a");
            let b = svc.register_tenant("b");
            let lane = svc.sphere_lane(Arc::clone(&off)).unwrap();
            let mut inputs = Vec::new();
            for (t, seed) in [(a, 1u64), (b, 2), (a, 3), (b, 4), (b, 5)] {
                let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
                let data = phased(slot.len(), seed);
                slot.data_mut().copy_from_slice(&data);
                inputs.push((t, data));
                svc.submit(t, lane, Direction::Forward, slot).unwrap();
            }
            assert_eq!(svc.flush(&backend, Direction::Forward), 5);
            let rec = *svc.flush_records().last().unwrap();
            assert_eq!((rec.jobs, rec.tenants), (5, 2));
            let got_a = svc.collect(a);
            let got_b = svc.collect(b);
            assert_eq!((got_a.len(), got_b.len()), (2, 3));

            // Sequential: a fresh service, each tenant flushed alone.
            let mut seq = service_on(p, &comm, CommTuning::default());
            let sa = seq.register_tenant("a");
            let sb = seq.register_tenant("b");
            let lane2 = seq.sphere_lane(Arc::clone(&off)).unwrap();
            assert_eq!(lane, lane2, "same sphere, same coalescing key");
            let mut seq_results = Vec::new();
            for t in [sa, sb] {
                for (owner, data) in &inputs {
                    if owner.index() != t.index() {
                        continue;
                    }
                    let mut slot = seq.checkout(t, lane2, Direction::Forward).unwrap();
                    slot.data_mut().copy_from_slice(data);
                    seq.submit(t, lane2, Direction::Forward, slot).unwrap();
                }
                seq.flush(&backend, Direction::Forward);
                seq_results.push(seq.collect(t));
            }

            // And the ground truth: a single-band plan per input.
            let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
            let single = PlaneWavePlan::new(Arc::clone(&off), 1, grid).unwrap();
            for (tenant_got, seq_got, tenant_idx) in
                [(&got_a, &seq_results[0], 0usize), (&got_b, &seq_results[1], 1)]
            {
                let mut band = 0;
                for (owner, data) in &inputs {
                    if owner.index() != tenant_idx {
                        continue;
                    }
                    let what = format!("p={p} tenant {tenant_idx} band {band}");
                    assert_slots_bits_eq(
                        tenant_got[band].1.data(),
                        seq_got[band].1.data(),
                        &format!("{what}: coalesced vs sequential"),
                    );
                    let (want, _) = single.forward(&backend, data.clone());
                    assert_slots_bits_eq(
                        tenant_got[band].1.data(),
                        &want,
                        &format!("{what}: coalesced vs single-band plan"),
                    );
                    band += 1;
                }
            }
            true
        });
        assert!(ok.into_iter().all(|b| b));
    }
}

/// Two k-point lanes: distinct crystal momenta get distinct lanes (their
/// offset spheres fingerprint apart even when the shift moves no grid
/// point), the same k re-requested lands back in its existing lane, and
/// one flush coalesces each k-lane's tenants separately — with every band
/// bit-identical to a single-band plan on that k's sphere.
#[test]
fn two_kpoint_lanes_coalesce_separately_and_share_by_fingerprint() {
    let p = 2usize;
    run_world(p, move |comm| {
        let spec = SphereSpec::new([8, 8, 8], 3.0, SphereKind::Wrapped);
        let k1 = Arc::new(spec.offset([0.25, 0.0, 0.0]));
        let k2 = Arc::new(spec.offset([0.0, 0.25, 0.0]));
        let mut svc = service_on(p, &comm, CommTuning::default());
        let a = svc.register_tenant("a");
        let b = svc.register_tenant("b");
        let lane1 = svc.sphere_lane(Arc::clone(&k1)).unwrap();
        let lane2 = svc.sphere_lane(Arc::clone(&k2)).unwrap();
        assert_ne!(lane1, lane2, "distinct k-points must get distinct lanes");
        assert_eq!(
            svc.sphere_lane(Arc::clone(&k1)).unwrap(),
            lane1,
            "the same k must land back in its lane"
        );

        let backend = RustFftBackend::new();
        // Sequence ids are handed out in submission order, so inputs[seq]
        // is the request a collected (seq, slot) pair answers.
        let mut inputs = Vec::new();
        for (t, lane, seed) in
            [(a, lane1, 1u64), (b, lane1, 2), (a, lane2, 3), (b, lane2, 4)]
        {
            let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
            let data = phased(slot.len(), seed);
            slot.data_mut().copy_from_slice(&data);
            inputs.push((lane, data));
            svc.submit(t, lane, Direction::Forward, slot).unwrap();
        }
        assert_eq!(svc.flush(&backend, Direction::Forward), 4);

        // One coalesced record per k-lane, each serving both tenants.
        let recs = svc.flush_records();
        let last2 = &recs[recs.len() - 2..];
        assert_ne!(last2[0].lane, last2[1].lane);
        for rec in last2 {
            assert_eq!((rec.jobs, rec.tenants), (2, 2), "lane {:#x}", rec.lane);
        }

        // Ground truth per k: a single-band plan on that k's own sphere.
        let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
        let single1 = PlaneWavePlan::new(Arc::clone(&k1), 1, Arc::clone(&grid)).unwrap();
        let single2 = PlaneWavePlan::new(Arc::clone(&k2), 1, grid).unwrap();
        for t in [a, b] {
            let got = svc.collect(t);
            assert_eq!(got.len(), 2, "one band per lane per tenant");
            for (seq, slot) in &got {
                let (lane, data) = &inputs[*seq as usize];
                let plan = if *lane == lane1 { &single1 } else { &single2 };
                let (want, _) = plan.forward(&backend, data.clone());
                assert_slots_bits_eq(
                    slot.data(),
                    &want,
                    &format!("p={p} lane {lane:#x} seq {seq}"),
                );
            }
        }
    });
}

/// Quota exhaustion and the backlog window reject with typed errors
/// through the public API, release the refused request's resources, and
/// recover as soon as a slot drops / a flush runs — never a panic, never
/// an unbounded queue.
#[test]
fn quota_and_backlog_reject_typed_and_recover() {
    run_world(1, |comm| {
        let off = sphere();
        let grid = ProcGrid::new(&[1], comm.clone()).unwrap();
        let config = ServiceConfig { max_in_flight: 2, ..Default::default() };
        let mut svc = TransformService::new([8, 8, 8], grid, config).unwrap();
        let lane = svc.sphere_lane(Arc::clone(&off)).unwrap();
        let slot_bytes = svc.slot_bytes(lane).unwrap();
        let t = svc.register_tenant_with_quota("tight", slot_bytes);
        let backend = RustFftBackend::new();

        // One slot fits; the second checkout is a typed refusal.
        let s1 = svc.checkout(t, lane, Direction::Forward).unwrap();
        match svc.checkout(t, lane, Direction::Forward) {
            Err(ServiceError::QuotaExhausted { tenant, requested, charged, quota }) => {
                assert_eq!(tenant, t.index());
                assert_eq!(requested, slot_bytes);
                assert_eq!(charged, slot_bytes);
                assert_eq!(quota, slot_bytes);
            }
            other => panic!("expected QuotaExhausted, got {other:?}"),
        }
        // Dropping the outstanding slot frees the lease immediately.
        drop(s1);
        assert_eq!(svc.tenant_charged(t), 0);

        // The in-flight window refuses the third submit and releases its
        // slot; a flush reopens the window.
        let roomy = svc.register_tenant("roomy");
        for _ in 0..2 {
            let slot = svc.checkout(roomy, lane, Direction::Forward).unwrap();
            svc.submit(roomy, lane, Direction::Forward, slot).unwrap();
        }
        let slot = svc.checkout(roomy, lane, Direction::Forward).unwrap();
        match svc.submit(roomy, lane, Direction::Forward, slot) {
            Err(ServiceError::Backlogged { pending: 2, limit: 2 }) => {}
            other => panic!("expected Backlogged, got {other:?}"),
        }
        assert_eq!(svc.pending(), 2);
        svc.flush(&backend, Direction::Forward);
        assert_eq!(svc.pending(), 0);
        drop(svc.collect(roomy));
        let slot = svc.checkout(roomy, lane, Direction::Forward).unwrap();
        assert!(svc.submit(roomy, lane, Direction::Forward, slot).is_ok());
    });
}

/// Steady-state contract over the sphere lane: from the second
/// forward/inverse round on, the tenant's slot pool mints nothing, the
/// lane's workspaces grow by zero bytes, and every flush is a plan-cache
/// hit.
#[test]
fn steady_state_sphere_round_trips_are_allocation_free() {
    let p = 2;
    run_world(p, move |comm| {
        let off = sphere();
        let mut svc = service_on(p, &comm, CommTuning::default());
        let t = svc.register_tenant("hot");
        let lane = svc.sphere_lane(Arc::clone(&off)).unwrap();
        let backend = RustFftBackend::new();
        let mut after_first = 0;
        for round in 0..4u64 {
            // Forward two bands, then send the dense results back through
            // the inverse — the full SCF-shaped round trip.
            for b in 0..2u64 {
                let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
                let data = phased(slot.len(), 10 * round + b);
                slot.data_mut().copy_from_slice(&data);
                svc.submit(t, lane, Direction::Forward, slot).unwrap();
            }
            svc.flush(&backend, Direction::Forward);
            for (_, slot) in svc.collect(t) {
                svc.submit(t, lane, Direction::Inverse, slot).unwrap();
            }
            svc.flush(&backend, Direction::Inverse);
            drop(svc.collect(t));
            if round == 0 {
                after_first = svc.tenant_alloc_bytes(t);
                assert!(after_first > 0, "the first round mints the working set");
            } else {
                assert_eq!(
                    svc.tenant_alloc_bytes(t),
                    after_first,
                    "round {round} must run out of recycled slots"
                );
                let recs = svc.flush_records();
                for rec in &recs[recs.len() - 2..] {
                    assert!(rec.plan_cache_hit, "round {round} must hit the plan cache");
                    assert_eq!(rec.alloc_bytes, 0, "round {round} workspace must be warm");
                }
            }
        }
        assert_eq!(svc.tenant_charged(t), 0, "all leases returned");
    });
}

/// Two SCF solvers through one service must produce, on every world size,
/// bit-identical scalars, eigenvalues and densities to each solver
/// running alone on a pinned plan — while the service's exchange count
/// stays strictly below the sum of the isolated runs' (the coalescing
/// win the layer exists for).
#[test]
fn service_scf_tenants_match_isolated_runs_across_world_sizes() {
    const N: usize = 12;
    const A: f64 = 8.0;
    const ECUT: f64 = 2.0;
    let iters = 3usize;
    for p in [1usize, 2, 4] {
        run_world(p, move |comm| {
            let lat = Lattice::new(A, N, ECUT);
            let backend = RustFftBackend::new();
            let pot_a = GaussianWells::single(1.0, 1.5);
            let pot_b = GaussianWells::single(3.0, 1.2);
            let opts_a = ScfOptions { max_iters: iters, tol: 0.0, ..Default::default() };
            let opts_b =
                ScfOptions { max_iters: iters, tol: 0.0, seed: 7, ..Default::default() };

            let mut driver =
                ScfServiceDriver::new(&lat, &comm, ServiceConfig::default()).unwrap();
            driver.add_tenant("a", lat.clone(), 2, &pot_a, &comm, opts_a.clone()).unwrap();
            driver.add_tenant("b", lat.clone(), 3, &pot_b, &comm, opts_b.clone()).unwrap();
            let results = driver.run(&backend).unwrap();
            for rec in driver.service().flush_records() {
                assert_eq!(rec.tenants, 2, "every flush must serve both tenants");
            }
            let coalesced_msgs = driver.service().metrics().total_messages();

            // The same two problems, each alone on a pinned plan.
            let mut isolated_msgs = 0u64;
            let mut isolated = Vec::new();
            for (nb, pot, opts) in [(2usize, &pot_a, &opts_a), (3, &pot_b, &opts_b)] {
                let grid = ProcGrid::new(&[p], comm.clone()).unwrap();
                let plan = PlaneWavePlan::new(Arc::clone(&lat.offsets), nb, grid).unwrap();
                let plan =
                    Arc::new(Fftb { kind: PlanKind::PlaneWave(plan), sizes: [N, N, N], nb });
                let mut runner =
                    ScfRunner::with_plan(lat.clone(), nb, pot, &comm, plan, opts.clone())
                        .unwrap();
                isolated.push(runner.run(&backend));
                for tr in runner.drain_traces() {
                    isolated_msgs += tr.comm_messages();
                }
            }
            if p > 1 {
                assert!(
                    coalesced_msgs < isolated_msgs,
                    "coalescing must cut exchanges: {coalesced_msgs} vs {isolated_msgs}"
                );
            }

            for (which, (svc, alone)) in
                [(&results[0], &isolated[0]), (&results[1], &isolated[1])].iter().enumerate()
            {
                assert_eq!(svc.history.len(), alone.history.len());
                for (s, t) in svc.history.iter().zip(&alone.history) {
                    for (x, y, what) in [
                        (s.charge, t.charge, "charge"),
                        (s.delta_rho, t.delta_rho, "delta_rho"),
                        (s.max_residual, t.max_residual, "max_residual"),
                        (s.energy.total, t.energy.total, "energy.total"),
                        (s.energy.hartree, t.energy.hartree, "energy.hartree"),
                    ] {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "p={p} tenant {which} iter {}: {what} differs ({x} vs {y})",
                            s.iter
                        );
                    }
                }
                for (x, y) in svc.eigenvalues.iter().zip(&alone.eigenvalues) {
                    assert_eq!(x.to_bits(), y.to_bits(), "p={p} tenant {which}: eigenvalue");
                }
                for (i, (x, y)) in
                    svc.density.rho.iter().zip(&alone.density.rho).enumerate()
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "p={p} tenant {which}: rho[{i}]");
                }
            }
        });
    }
}

/// The perturbation gauntlet through the service path: coalesced
/// multi-tenant forward + inverse flushes must be bit-identical under
/// seeded delivery/wait perturbation, with the exchange's helper worker
/// both off and on.
#[test]
fn perturbed_service_flushes_are_bit_identical() {
    for p in [2usize, 3, 5] {
        let body = move |worker: bool| {
            move |comm: Comm| {
                let off = sphere();
                let tuning = CommTuning::with_window(2).with_worker(worker);
                let mut svc = service_on(p, &comm, tuning);
                let a = svc.register_tenant("a");
                let b = svc.register_tenant("b");
                let lane = svc.sphere_lane(off).unwrap();
                let backend = RustFftBackend::new();
                let mut bits = Vec::new();
                for round in 0..2u64 {
                    for (t, seed) in [(a, 1u64), (b, 2), (a, 3)] {
                        let mut slot = svc.checkout(t, lane, Direction::Forward).unwrap();
                        let data = phased(slot.len(), 100 * round + seed);
                        slot.data_mut().copy_from_slice(&data);
                        svc.submit(t, lane, Direction::Forward, slot).unwrap();
                    }
                    svc.flush(&backend, Direction::Forward);
                    for t in [a, b] {
                        for (_, slot) in svc.collect(t) {
                            bits.extend(slot.data().iter().copied());
                            svc.submit(t, lane, Direction::Inverse, slot).unwrap();
                        }
                    }
                    svc.flush(&backend, Direction::Inverse);
                    for t in [a, b] {
                        for (_, slot) in svc.collect(t) {
                            bits.extend(slot.data().iter().copied());
                        }
                    }
                }
                bits
            }
        };
        let base = run_world(p, body(false));
        let threaded = run_world(p, body(true));
        for (r, (x, y)) in base.iter().zip(&threaded).enumerate() {
            assert_slots_bits_eq(x, y, &format!("p={p} rank {r} worker-on unperturbed"));
        }
        for seed in [1u64, 23, 0xDEAD_BEEF] {
            for worker in [false, true] {
                let got = run_world_perturbed(p, seed, body(worker));
                for (r, (x, y)) in base.iter().zip(&got).enumerate() {
                    assert_slots_bits_eq(
                        x,
                        y,
                        &format!("p={p} rank {r} seed={seed} worker={worker}"),
                    );
                }
            }
        }
    }
}
