"""AOT lowering: every L2 entry point -> HLO *text* + a JSON manifest.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids, which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly — see /opt/xla-example/README.md.

Usage: `python -m compile.aot --out-dir ../artifacts` (from python/);
`make artifacts` is the canonical entry and skips the build when inputs are
unchanged.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # `{...}`, which the xla 0.5.1 text parser silently reads as zeros —
    # the DFT matrices MUST be printed in full.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(n) for n in model.LINE_SIZES),
        help="comma-separated line sizes to compile",
    )
    ap.add_argument("--batch", type=int, default=model.BATCH)
    args = ap.parse_args()

    sizes = tuple(int(s) for s in args.sizes.split(","))
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"batch": args.batch, "entries": []}
    for name, (fn, specs) in model.entries(sizes, args.batch).items():
        text = lower_entry(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in specs],
            }
        )
        print(f"  lowered {name:>24} -> {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} entries to {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
