"""L1 Pallas kernel: four-step (Stockham/Bailey) factorized DFT.

For line lengths past the dense-matmul sweet spot, factor n = n1 * n2 and
run two MXU matmul stages with a twiddle pointwise in between — the TPU
rendering of the Cooley-Tukey split the paper's Eq. (5)/(7) uses:

    input line x[j], j = j2 + n2*j1          (j1 in [n1], j2 in [n2])
    A[j2, k1] = sum_j1 x[j2 + n2*j1] W1[j1, k1]      # (n2,n1) @ (n1,n1)
    B[j2, k1] = A[j2, k1] * T[j2, k1],  T = w_n^{j2*k1} (forward)
    X[k1 + n1*k2] = sum_j2 B[j2, k1] W2[j2, k2]      # contract j2

Cost: 2 matmul stages of O(n*(n1+n2)) vs the dense O(n^2) — at n = 4096 =
64*64 that's a 32x MAC reduction while staying MXU-shaped.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

TILE_B = 8  # lines per program instance (each line is an (n2, n1) panel)


def _four_step_kernel(
    xr_ref, xi_ref, w1r_ref, w1i_ref, tr_ref, ti_ref, w2r_ref, w2i_ref, yr_ref, yi_ref
):
    """x: (TILE_B, n2, n1) split planes -> y: (TILE_B, n1, n2)."""
    xr = xr_ref[...]
    xi = xi_ref[...]
    w1r = w1r_ref[...]
    w1i = w1i_ref[...]

    # Stage 1: contract j1 (last axis of x) with W1 -> A[b, j2, k1].
    ar = jnp.einsum("bji,ik->bjk", xr, w1r) - jnp.einsum("bji,ik->bjk", xi, w1i)
    ai = jnp.einsum("bji,ik->bjk", xr, w1i) + jnp.einsum("bji,ik->bjk", xi, w1r)

    # Stage 2: twiddle T[j2, k1].
    tr = tr_ref[...]
    ti = ti_ref[...]
    br = ar * tr - ai * ti
    bi = ar * ti + ai * tr

    # Stage 3: contract j2 -> X[b, k1, k2].
    w2r = w2r_ref[...]
    w2i = w2i_ref[...]
    yr_ref[...] = jnp.einsum("bjk,jl->bkl", br, w2r) - jnp.einsum("bjk,jl->bkl", bi, w2i)
    yi_ref[...] = jnp.einsum("bjk,jl->bkl", br, w2i) + jnp.einsum("bjk,jl->bkl", bi, w2r)


@functools.partial(jax.jit, static_argnames=("n1", "n2", "forward"))
def four_step_dft_lines(x_ri, n1: int, n2: int, forward: bool = True):
    """Batched length-(n1*n2) DFT via the four-step factorization.

    x_ri: (B, n, 2) float32, B a multiple of TILE_B, n = n1*n2.
    Returns (B, n, 2), bit-compatible with jnp.fft up to f32 rounding.
    """
    b, n, _ = x_ri.shape
    assert n == n1 * n2, f"n={n} != n1*n2={n1 * n2}"
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"

    w1 = ref.dft_matrix(n1, forward)
    w2 = ref.dft_matrix(n2, forward)
    if not forward:
        # dft_matrix folds 1/n1 and 1/n2 into the stages: total 1/n. Correct.
        pass
    sign = -2j if forward else 2j
    t = np.exp(sign * np.pi * np.outer(np.arange(n2), np.arange(n1)) / n)

    # x[j2 + n2*j1] -> panel [j2, j1]: reshape (B, n1, n2) then transpose.
    xr = x_ri[..., 0].reshape(b, n1, n2).transpose(0, 2, 1)
    xi = x_ri[..., 1].reshape(b, n1, n2).transpose(0, 2, 1)

    consts = [
        jnp.asarray(w1.real, jnp.float32),
        jnp.asarray(w1.imag, jnp.float32),
        jnp.asarray(t.real, jnp.float32),
        jnp.asarray(t.imag, jnp.float32),
        jnp.asarray(w2.real, jnp.float32),
        jnp.asarray(w2.imag, jnp.float32),
    ]
    grid = (b // TILE_B,)
    yr, yi = pl.pallas_call(
        _four_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, n2, n1), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_B, n2, n1), lambda i: (i, 0, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n1, n1), lambda i: (0, 0)),
            pl.BlockSpec((n2, n1), lambda i: (0, 0)),
            pl.BlockSpec((n2, n1), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
            pl.BlockSpec((n2, n2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, n1, n2), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_B, n1, n2), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n1, n2), jnp.float32),
            jax.ShapeDtypeStruct((b, n1, n2), jnp.float32),
        ],
        interpret=True,
    )(xr, xi, *consts)

    # X[k1 + n1*k2] <- panel [k1, k2]: transpose back and flatten with k1
    # fastest.
    yr = yr.transpose(0, 2, 1).reshape(b, n)
    yi = yi.transpose(0, 2, 1).reshape(b, n)
    return jnp.stack([yr, yi], axis=-1)


def macs(b: int, n1: int, n2: int) -> int:
    """MXU MACs per call (both stages, 4 real matmuls each)."""
    n = n1 * n2
    return 4 * 2 * b * (n * n1 + n * n2)
