"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Everything at the artifact boundary is float32 with a trailing re/im axis
(`(..., 2)` "ri" layout): the rust `xla` crate has no complex NativeType, so
complex never crosses the PJRT boundary. These helpers convert between the
ri layout and jnp complex, and give the reference answers (`jnp.fft`) that
every kernel and every AOT artifact is validated against.
"""

import jax.numpy as jnp
import numpy as np

# Forward DFT uses exp(-2*pi*i/n) (numpy/paper convention); inverse is the
# conjugate scaled by 1/n.


def to_ri(c):
    """complex (...,) -> float32 (..., 2)."""
    return jnp.stack([jnp.real(c), jnp.imag(c)], axis=-1).astype(jnp.float32)


def from_ri(x):
    """float32 (..., 2) -> complex64 (...,)."""
    return x[..., 0] + 1j * x[..., 1]


def dft_matrix(n: int, forward: bool = True) -> np.ndarray:
    """Dense DFT matrix W with W[j, k] = w_n^{jk}. y = x @ W matches
    jnp.fft.fft(x) for row vectors x (complex128 for accuracy; cast where
    consumed). The inverse matrix folds in the 1/n scale.
    """
    sign = -2j if forward else 2j
    j = np.arange(n)
    w = np.exp(sign * np.pi * np.outer(j, j) / n)
    if not forward:
        w = w / n
    return w


def dft_pad_matrix(m: int, n: int, offset: int, forward: bool = True) -> np.ndarray:
    """The fused zero-pad + DFT operator (paper Fig. 3 insight, MXU form):

    DFT_n of a length-n line that is zero outside `offset : offset+m` equals
    the (m x n) slice W[offset:offset+m, :] applied to the m nonzeros —
    the padding never materializes.
    """
    return dft_matrix(n, forward)[offset : offset + m, :]


def fft_lines_ref(x_ri, forward: bool = True):
    """Reference batched line FFT on ri data: (B, n, 2) -> (B, n, 2)."""
    c = from_ri(x_ri)
    y = jnp.fft.fft(c, axis=-1) if forward else jnp.fft.ifft(c, axis=-1)
    return to_ri(y)


def pad_fft_lines_ref(x_ri, n: int, offset: int, forward: bool = True):
    """Reference fused pad+FFT: (B, m, 2) -> (B, n, 2)."""
    c = from_ri(x_ri)
    b, m = c.shape
    z = jnp.zeros((b, n), dtype=c.dtype)
    z = z.at[:, offset : offset + m].set(c)
    y = jnp.fft.fft(z, axis=-1) if forward else jnp.fft.ifft(z, axis=-1)
    return to_ri(y)


def fft3d_ref(x_ri, forward: bool = True):
    """Reference 3D FFT on ri data: (nx, ny, nz, 2), transform all 3 dims."""
    c = from_ri(x_ri)
    y = jnp.fft.fftn(c, axes=(0, 1, 2)) if forward else jnp.fft.ifftn(c, axes=(0, 1, 2))
    return to_ri(y)
