"""L1 Pallas kernel: batched 1D (zero-padded) DFT as MXU matmuls.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's per-GPU
hot spot is cuFFT batched butterfly kernels. On TPU the natural shape of a
batched line DFT for n <= ~256 is a dense contraction on the MXU systolic
array: `(tile_b, m) @ (m, n)` with the (possibly sliced) DFT matrix resident
in VMEM. Complex arithmetic runs on split re/im planes — four real matmuls —
so the MXU sees plain f32 GEMMs.

The same kernel implements the paper's *fused zero-pad + FFT* (Fig. 3):
padding a length-m run to n at `offset` before an n-point DFT is exactly the
(m x n) slice `W[offset:offset+m, :]` — so the padded elements never exist.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is estimated from the VMEM/MXU model in
DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Batch tile: one program instance transforms TILE_B lines. Chosen so the
# VMEM working set (tile panel + W + output, f32) stays far under 16 MiB:
# 64*(2*256)*4 + 2*256*256*4 + 64*(2*256)*4 ~ 0.8 MiB at n=256.
TILE_B = 64


def _dft_kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    """One (TILE_B, m) panel x (m, n) DFT matrix -> (TILE_B, n) panel.

    Complex multiply on split planes:
        yr = xr @ wr - xi @ wi
        yi = xr @ wi + xi @ wr
    """
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    yr_ref[...] = jnp.dot(xr, wr, preferred_element_type=jnp.float32) - jnp.dot(
        xi, wi, preferred_element_type=jnp.float32
    )
    yi_ref[...] = jnp.dot(xr, wi, preferred_element_type=jnp.float32) + jnp.dot(
        xi, wr, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("n", "offset", "forward"))
def pad_dft_lines(x_ri, n: int, offset: int = 0, forward: bool = True):
    """Batched fused pad+DFT of ri lines.

    x_ri: (B, m, 2) float32, B a multiple of TILE_B (pad the tail tile with
    zero lines upstream). Returns (B, n, 2). With m == n, offset == 0 this is
    a plain batched DFT.
    """
    b, m, _ = x_ri.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    assert offset + m <= n, "padded run exceeds line length"
    w = ref.dft_pad_matrix(m, n, offset, forward)
    wr = jnp.asarray(w.real, jnp.float32)
    wi = jnp.asarray(w.imag, jnp.float32)
    xr = x_ri[..., 0]
    xi = x_ri[..., 1]

    grid = (b // TILE_B,)
    yr, yi = pl.pallas_call(
        _dft_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=True,
    )(xr, xi, wr, wi)
    return jnp.stack([yr, yi], axis=-1)


def dft_lines(x_ri, forward: bool = True):
    """Plain batched DFT: (B, n, 2) -> (B, n, 2)."""
    n = x_ri.shape[1]
    return pad_dft_lines(x_ri, n=n, offset=0, forward=forward)


def vmem_bytes(m: int, n: int, tile_b: int = TILE_B) -> int:
    """VMEM working set of one program instance (f32)."""
    return 4 * (2 * tile_b * m + 2 * m * n + 2 * tile_b * n)


def mxu_flops(b: int, m: int, n: int) -> int:
    """Real MACs issued to the MXU per call: 4 matmuls of (b, m) @ (m, n)."""
    return 4 * 2 * b * m * n
