"""L2: the per-rank local compute graphs, in JAX, calling the L1 kernels.

The distributed FFTB plans (rust L3) hand every local transform to a
backend as a contiguous batch of lines (see `rust/src/fftb/backend.rs`).
The artifacts compiled here are exactly those batches:

* ``fft{n}_{f,i}``   — batched line DFT, (B, n, 2) -> (B, n, 2), the hot
  path of every plan stage. Dense MXU matmul for small n, four-step
  factorization for large n.
* ``padfft_{m}_{n}_{o}_{f}`` — fused zero-pad + DFT (the plane-wave staged
  padding of Fig. 3), (B, m, 2) -> (B, n, 2).
* ``slab_yz_{ny}_{nz}`` — a fused two-dimension local pipeline (FFT along
  y then z of an (lx, ny, nz) slab), demonstrating stage fusion at the XLA
  level: the transposes between the line batches fuse into the surrounding
  copies instead of materializing in rust.

Python runs ONCE at build time (`make artifacts`); none of this is on the
request path.
"""

import jax
import jax.numpy as jnp

from .kernels import dft_matmul, stockham

# Artifact batch tile: every fft entry is compiled for this many lines.
# The rust runtime loops full tiles and zero-pads the tail.
BATCH = 64

# Line lengths compiled by default: the FFT grid sizes of the paper's
# experiments (256^3 cube, 128-diameter spheres) and the small sizes the
# tests/examples use.
LINE_SIZES = (8, 16, 32, 64, 128, 256)

# Above this, the four-step factorization beats the dense matmul.
FOUR_STEP_MIN = 128


def factor_four_step(n: int):
    """Pick n1*n2 = n with n1, n2 as square as possible (powers of 2)."""
    n1 = 1
    while n1 * n1 < n:
        n1 *= 2
    n2 = n // n1
    assert n1 * n2 == n, f"n={n} not factorable as pow2 pair"
    return n1, n2


def fft_lines(x_ri, forward: bool = True):
    """Batched line DFT, dispatching dense-matmul vs four-step by size."""
    n = x_ri.shape[1]
    if n >= FOUR_STEP_MIN and (n & (n - 1)) == 0:
        n1, n2 = factor_four_step(n)
        return stockham.four_step_dft_lines(x_ri, n1=n1, n2=n2, forward=forward)
    return dft_matmul.dft_lines(x_ri, forward=forward)


def pad_fft_lines(x_ri, n: int, offset: int, forward: bool = True):
    """Fused zero-pad + DFT of batched runs (plane-wave z/y stages)."""
    return dft_matmul.pad_dft_lines(x_ri, n=n, offset=offset, forward=forward)


def slab_yz(x_ri, forward: bool = True):
    """Local slab stage of the slab-pencil plan: FFT along y then z of an
    (LX, ny, nz, 2) slab. The line batches run through the Pallas kernels;
    XLA fuses the interleaving transposes.
    """
    lx, ny, nz, _ = x_ri.shape
    # FFT along y: lines are (lx*nz, ny).
    t = jnp.transpose(x_ri, (0, 2, 1, 3)).reshape(lx * nz, ny, 2)
    t = fft_lines(t, forward)
    t = t.reshape(lx, nz, ny, 2)
    # FFT along z: lines are (lx*ny, nz).
    t = jnp.transpose(t, (0, 2, 1, 3)).reshape(lx * ny, nz, 2)
    t = fft_lines(t, forward)
    return t.reshape(lx, ny, nz, 2)


# ---------------------------------------------------------------------------
# AOT entry-point registry: name -> (function, example input shapes).
# ---------------------------------------------------------------------------


def entries(line_sizes=LINE_SIZES, batch=BATCH):
    """All artifact entry points as {name: (fn, [input ShapeDtypeStructs])}."""
    out = {}
    f32 = jnp.float32
    for n in line_sizes:
        spec = jax.ShapeDtypeStruct((batch, n, 2), f32)
        out[f"fft{n}_f"] = (lambda x, n=n: fft_lines(x, True), [spec])
        out[f"fft{n}_i"] = (lambda x, n=n: fft_lines(x, False), [spec])
    # One demonstration pad+FFT entry (m = n/2 run centred in the line, the
    # d = n/2 sphere's largest column) per size, forward only.
    for n in line_sizes:
        m = n // 2
        o = n // 4
        spec = jax.ShapeDtypeStruct((batch, m, 2), f32)
        out[f"padfft_{m}_{n}_{o}_f"] = (
            lambda x, n=n, o=o: pad_fft_lines(x, n=n, offset=o, forward=True),
            [spec],
        )
    # Fused local slab pipeline at a test-friendly size.
    out["slab_yz_16_16"] = (
        lambda x: slab_yz(x, True),
        [jax.ShapeDtypeStruct((4, 16, 16, 2), f32)],
    )
    return out
