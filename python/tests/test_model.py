"""L2 model-level tests: pipeline composition + AOT lowering shape checks."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def rand_ri(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape + (2,)).astype(np.float32)


def test_fft_lines_dispatch_small_and_large():
    for n in (16, 128):
        b = model.BATCH
        x = rand_ri((b, n), seed=n)
        got = model.fft_lines(x, forward=True)
        want = ref.fft_lines_ref(x, forward=True)
        scale = float(np.max(np.abs(np.asarray(want))))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-3 * max(scale, 1.0)
        )


def test_factor_four_step():
    assert model.factor_four_step(256) == (16, 16)
    assert model.factor_four_step(128) == (16, 8)
    assert model.factor_four_step(64) == (8, 8)


def test_slab_yz_matches_fftn():
    lx, ny, nz = 4, 16, 16
    x = rand_ri((lx, ny, nz), seed=2)
    got = np.asarray(model.slab_yz(x, forward=True))
    c = ref.from_ri(x)
    want = np.asarray(ref.to_ri(jnp.fft.fftn(c, axes=(1, 2))))
    scale = max(np.max(np.abs(want)), 1.0)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-3 * scale)


def test_entries_shapes_consistent():
    es = model.entries(line_sizes=(8, 16), batch=model.BATCH)
    assert "fft8_f" in es and "fft16_i" in es
    fn, specs = es["fft8_f"]
    out = jax.eval_shape(fn, *specs)
    assert out.shape == (model.BATCH, 8, 2)
    fn, specs = es["padfft_4_8_2_f"]
    out = jax.eval_shape(fn, *specs)
    assert out.shape == (model.BATCH, 8, 2)


def test_aot_lowering_produces_hlo_text():
    es = model.entries(line_sizes=(8,), batch=model.BATCH)
    fn, specs = es["fft8_f"]
    text = aot.lower_entry(fn, specs)
    assert "HloModule" in text
    assert "f32[64,8,2]" in text.replace(" ", "")


@pytest.mark.slow
def test_aot_main_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", d, "--sizes", "8,16"]
        try:
            aot.main()
        finally:
            sys.argv = argv
        import json

        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        names = {e["name"] for e in manifest["entries"]}
        assert {"fft8_f", "fft8_i", "fft16_f", "fft16_i"} <= names
        for e in manifest["entries"]:
            assert os.path.exists(os.path.join(d, e["file"]))
