"""Property sweeps of the L1/L2 stack under hypothesis: transform axioms
(linearity, Parseval, shift) must hold for the Pallas kernels, not just
pointwise agreement with the oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import dft_matmul, ref


def rand_ri(b, n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((b, n, 2)).astype(np.float32)


def to_c(x):
    return np.asarray(x)[..., 0] + 1j * np.asarray(x)[..., 1]


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(2, 5), seed=st.integers(0, 10_000))
def test_linearity(logn, seed):
    n = 1 << logn
    b = dft_matmul.TILE_B
    x = rand_ri(b, n, seed)
    y = rand_ri(b, n, seed + 1)
    a = 0.73
    fx = to_c(dft_matmul.dft_lines(x))
    fy = to_c(dft_matmul.dft_lines(y))
    fxy = to_c(dft_matmul.dft_lines((a * x + y).astype(np.float32)))
    np.testing.assert_allclose(fxy, a * fx + fy, rtol=2e-3, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(logn=st.integers(2, 6), seed=st.integers(0, 10_000))
def test_parseval(logn, seed):
    n = 1 << logn
    x = rand_ri(dft_matmul.TILE_B, n, seed)
    fx = to_c(dft_matmul.dft_lines(x))
    ex = np.sum(np.abs(to_c(x)) ** 2, axis=-1)
    ef = np.sum(np.abs(fx) ** 2, axis=-1) / n
    np.testing.assert_allclose(ef, ex, rtol=5e-3)


@settings(max_examples=8, deadline=None)
@given(logn=st.integers(3, 5), seed=st.integers(0, 10_000), data=st.data())
def test_shift_theorem(logn, seed, data):
    n = 1 << logn
    s = data.draw(st.integers(0, n - 1))
    x = rand_ri(dft_matmul.TILE_B, n, seed)
    shifted = np.roll(x, -s, axis=1)
    fx = to_c(dft_matmul.dft_lines(x))
    fs = to_c(dft_matmul.dft_lines(shifted))
    k = np.arange(n)
    phase = np.exp(2j * np.pi * s * k / n)
    np.testing.assert_allclose(fs, fx * phase, rtol=5e-3, atol=2e-2)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_model_fft_lines_round_trip(seed):
    n = 64
    x = rand_ri(model.BATCH, n, seed)
    y = model.fft_lines(x, forward=True)
    z = np.asarray(model.fft_lines(np.asarray(y), forward=False))
    np.testing.assert_allclose(z, x, rtol=1e-3, atol=1e-3)


def test_pad_matrix_is_dft_slice():
    w = ref.dft_matrix(16, True)
    p = ref.dft_pad_matrix(8, 16, 4, True)
    np.testing.assert_allclose(p, w[4:12, :])
