"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal of the compile path: every artifact the
rust runtime executes is one of these functions.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dft_matmul, ref, stockham

RTOL = 2e-4  # f32 kernels vs complex128-backed oracle
ATOL = 1e-3


def rand_ri(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((b, n, 2)).astype(np.float32)


def assert_close(got, want, n):
    scale = max(np.max(np.abs(want)), 1.0)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL * scale
    )


# ---------------------------------------------------------------- dft_matmul


@pytest.mark.parametrize("n", [4, 8, 16, 32, 64])
@pytest.mark.parametrize("forward", [True, False])
def test_dft_lines_matches_jnp_fft(n, forward):
    x = rand_ri(dft_matmul.TILE_B, n, seed=n)
    got = dft_matmul.dft_lines(x, forward=forward)
    want = ref.fft_lines_ref(x, forward=forward)
    assert_close(got, want, n)


def test_dft_lines_multi_tile():
    n = 16
    x = rand_ri(3 * dft_matmul.TILE_B, n, seed=5)
    got = dft_matmul.dft_lines(x, forward=True)
    want = ref.fft_lines_ref(x, forward=True)
    assert_close(got, want, n)


def test_dft_lines_rejects_partial_tile():
    with pytest.raises(AssertionError):
        dft_matmul.dft_lines(rand_ri(dft_matmul.TILE_B + 1, 8))


@pytest.mark.parametrize("m,n,o", [(4, 8, 0), (4, 8, 2), (8, 16, 4), (16, 32, 8)])
def test_pad_dft_fuses_padding(m, n, o):
    x = rand_ri(dft_matmul.TILE_B, m, seed=m + n + o)
    got = dft_matmul.pad_dft_lines(x, n=n, offset=o, forward=True)
    want = ref.pad_fft_lines_ref(x, n=n, offset=o, forward=True)
    assert_close(got, want, n)


def test_round_trip_forward_inverse():
    n = 32
    x = rand_ri(dft_matmul.TILE_B, n, seed=9)
    y = dft_matmul.dft_lines(x, forward=True)
    z = dft_matmul.dft_lines(np.asarray(y), forward=False)
    np.testing.assert_allclose(np.asarray(z), x, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    logn=st.integers(2, 6),
    seed=st.integers(0, 2**31 - 1),
    forward=st.booleans(),
)
def test_dft_lines_hypothesis_sweep(logn, seed, forward):
    """Hypothesis sweep over shapes/directions against the oracle."""
    n = 1 << logn
    x = rand_ri(dft_matmul.TILE_B, n, seed=seed)
    got = dft_matmul.dft_lines(x, forward=forward)
    want = ref.fft_lines_ref(x, forward=forward)
    assert_close(got, want, n)


@settings(max_examples=15, deadline=None)
@given(
    m_exp=st.integers(1, 4),
    n_exp=st.integers(3, 6),
    seed=st.integers(0, 1000),
    data=st.data(),
)
def test_pad_dft_hypothesis_sweep(m_exp, n_exp, seed, data):
    m, n = 1 << m_exp, 1 << n_exp
    if m > n:
        m, n = n, m
    o = data.draw(st.integers(0, n - m))
    x = rand_ri(dft_matmul.TILE_B, m, seed=seed)
    got = dft_matmul.pad_dft_lines(x, n=n, offset=o, forward=True)
    want = ref.pad_fft_lines_ref(x, n=n, offset=o, forward=True)
    assert_close(got, want, n)


# ------------------------------------------------------------------ stockham


@pytest.mark.parametrize("n1,n2", [(4, 4), (8, 8), (8, 16), (16, 16)])
@pytest.mark.parametrize("forward", [True, False])
def test_four_step_matches_jnp_fft(n1, n2, forward):
    n = n1 * n2
    x = rand_ri(stockham.TILE_B, n, seed=n)
    got = stockham.four_step_dft_lines(x, n1=n1, n2=n2, forward=forward)
    want = ref.fft_lines_ref(x, forward=forward)
    assert_close(got, want, n)


def test_four_step_equals_dense_matmul():
    n1, n2 = 8, 8
    n = n1 * n2
    b = 64  # multiple of both TILE_Bs
    x = rand_ri(b, n, seed=3)
    a = stockham.four_step_dft_lines(x, n1=n1, n2=n2, forward=True)
    d = dft_matmul.dft_lines(x, forward=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(d), rtol=1e-3, atol=1e-2)


def test_four_step_mac_savings():
    # The factorization must actually reduce MXU work.
    b, n1, n2 = 64, 16, 16
    n = n1 * n2
    dense = dft_matmul.mxu_flops(b, n, n)
    four = stockham.macs(b, n1, n2)
    assert four * 4 < dense


# ------------------------------------------------------------------- oracle


def test_oracle_round_trip():
    x = rand_ri(4, 16, seed=1)
    y = ref.fft_lines_ref(x, forward=True)
    z = ref.fft_lines_ref(np.asarray(y), forward=False)
    np.testing.assert_allclose(np.asarray(z), x, rtol=1e-4, atol=1e-4)


def test_dft_matrix_matches_fft():
    n = 16
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    got = x @ ref.dft_matrix(n, True)
    want = np.fft.fft(x)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)
    got_i = x @ ref.dft_matrix(n, False)
    want_i = np.fft.ifft(x)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-10, atol=1e-10)
